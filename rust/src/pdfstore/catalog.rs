//! The generational run catalog: the store's single source of truth.
//!
//! The paper's workflow refits the same spatial data many times — per
//! method, per candidate-type set, per experiment rerun — and explicitly
//! reuses "previous results" across runs. A last-writer-wins manifest
//! (the store's first incarnation) silently clobbers exactly the runs
//! you would want to compare against. The catalog fixes that by making
//! persisted output **immutable and generational**, the same
//! partition-indexed organization the Random Sample Partition model
//! argues for (Salloum et al., arXiv 1712.04146):
//!
//! * A **run** is identified by `(method, types, run_id)`. Every run
//!   owns its own segment files; two runs never share or overwrite a
//!   file.
//! * Within a run, each written segment carries a **generation**
//!   number. Re-persisting a slice in the same run appends a new
//!   generation instead of truncating the old file; readers resolve
//!   window-by-window to the newest generation
//!   ([`RunEntry::resolve_slice`]). Compaction
//!   ([`crate::pdfstore::compact`]) rewrites the resolved view as one
//!   dense generation and retires the rest.
//! * The catalog itself (`CATALOG.json`) is a checksummed JSON document
//!   swapped atomically (tmp + rename), so the store on disk is always
//!   openable: a crash mid-write or mid-compaction leaves stray files
//!   the catalog simply does not reference.
//!
//! Nothing in a store directory is trusted unless the catalog names it;
//! that is what makes crash recovery a no-op.

use std::collections::HashSet;
use std::path::Path;

use crate::cube::CubeDims;
use crate::pdfstore::fnv64;
use crate::pdfstore::segment::{SegmentMeta, WindowEntry};
use crate::util::json::Json;
use crate::{PdfflowError, Result};

/// Catalog file name inside a store directory.
pub const CATALOG_NAME: &str = "CATALOG.json";
/// Manifest file name of the pre-generational store format; detected
/// only to fail with a diagnosable error instead of orphaning the data.
pub const LEGACY_MANIFEST_NAME: &str = "MANIFEST.json";
/// Catalog format version (bumped from the manifest-era 1; v3 added
/// per-segment covered-line ranges, paired with segment format v2's
/// per-window payload checksums).
pub const CATALOG_VERSION: u32 = 3;
/// The run id used when none is configured (`--run-id` / config).
pub const DEFAULT_RUN_ID: &str = "default";

/// Identity of one run: the paper's experiment coordinates plus a
/// user-chosen rerun label.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct RunKey {
    pub method: String,
    /// Candidate-type count of the run (4 or 10 in the paper).
    pub types: usize,
    pub run_id: String,
}

impl RunKey {
    pub fn new(method: &str, types: usize, run_id: &str) -> RunKey {
        RunKey {
            method: method.to_string(),
            types,
            run_id: run_id.to_string(),
        }
    }

    /// Human-readable `run/method/types` label for reports.
    pub fn label(&self) -> String {
        format!("{}/{}/{}", self.run_id, self.method, self.types)
    }
}

/// Run ids become file-name components, so they are restricted to a
/// safe alphabet. Rejecting here keeps every later path join trivial.
/// `"latest"` is reserved: the run selector resolves it to the most
/// recently written run, so a run actually named that would be
/// unaddressable.
pub fn validate_run_id(id: &str) -> Result<()> {
    if id == "latest" {
        return Err(PdfflowError::InvalidArg(
            "run id \"latest\" is reserved for run selection".into(),
        ));
    }
    let ok = !id.is_empty()
        && id.len() <= 64
        && id
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if ok {
        Ok(())
    } else {
        Err(PdfflowError::InvalidArg(format!(
            "run id {id:?} must be 1..=64 chars of [A-Za-z0-9._-]"
        )))
    }
}

/// One run's catalog entry: identity, recency, and its segment list
/// (all generations; resolution picks among them at read time).
#[derive(Clone, Debug)]
pub struct RunEntry {
    pub key: RunKey,
    /// Store-wide monotone sequence of this run's last update; the
    /// "latest" run is the one with the highest `seq` (no wall-clock in
    /// the format, so the ordering is deterministic and testable).
    pub seq: u64,
    pub segments: Vec<SegmentMeta>,
}

/// One resolved window of a slice: which segment (by index into `segs`
/// as passed to [`RunEntry::resolve_slice`]) and which window entry of
/// its footer serves these lines.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResolvedWindow {
    /// Index into the segment list the resolution ran over.
    pub seg: usize,
    /// Window index inside that segment's footer.
    pub win: usize,
    pub entry: WindowEntry,
}

impl RunEntry {
    /// Highest generation number present in this run, if any.
    pub fn max_gen(&self) -> Option<usize> {
        self.segments.iter().map(|s| s.gen).max()
    }

    /// Distinct generation count (what compaction collapses to 1).
    pub fn n_generations(&self) -> usize {
        let mut gens: Vec<usize> = self.segments.iter().map(|s| s.gen).collect();
        gens.sort_unstable();
        gens.dedup();
        gens.len()
    }

    /// Generation the next segment written for `slice` must carry: one
    /// past the newest existing generation of that slice (0 for a slice
    /// this run has never persisted). This is what turns a rerun into
    /// an append instead of an overwrite.
    pub fn next_gen_for_slice(&self, slice: usize) -> usize {
        self.segments
            .iter()
            .filter(|s| s.slice == slice)
            .map(|s| s.gen + 1)
            .max()
            .unwrap_or(0)
    }

    /// Slices this run has persisted, ascending.
    pub fn slices(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self.segments.iter().map(|s| s.slice).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Resolve one slice's readable windows: newest generation wins,
    /// whole-window shadowing. Segments are scanned newest generation
    /// first (ties broken toward the later catalog entry — the later
    /// write); a window is accepted when its line range overlaps no
    /// already-accepted window, and skipped when newer windows cover
    /// it entirely. A *partially* covered window — a rerun that used a
    /// different window grid — is a hard error: silently dropping it
    /// would lose the lines the newer generation did not rewrite, and
    /// a later compaction would make that loss permanent. The result is
    /// sorted by `y0` and non-overlapping — exactly the view compaction
    /// materializes, which is why queries are bit-identical before and
    /// after a compact.
    ///
    /// `windows_of(i)` supplies segment `i`'s decoded footer entries
    /// (the caller owns the open readers; the catalog itself never
    /// touches segment files).
    pub fn resolve_slice(
        &self,
        slice: usize,
        windows_of: impl Fn(usize) -> Vec<WindowEntry>,
    ) -> Result<Vec<ResolvedWindow>> {
        let mut order: Vec<usize> = (0..self.segments.len())
            .filter(|&i| self.segments[i].slice == slice)
            .collect();
        // Newest generation first; within a generation, the later
        // catalog entry (the later finished write) first.
        order.sort_by(|&a, &b| {
            self.segments[b]
                .gen
                .cmp(&self.segments[a].gen)
                .then(b.cmp(&a))
        });
        let mut accepted: Vec<ResolvedWindow> = Vec::new();
        for seg in order {
            for (win, entry) in windows_of(seg).into_iter().enumerate() {
                let (lo, hi) = (entry.y0, entry.y0 + entry.lines);
                let overlaps = accepted
                    .iter()
                    .any(|a| a.entry.y0 < hi && lo < a.entry.y0 + a.entry.lines);
                if !overlaps {
                    accepted.push(ResolvedWindow { seg, win, entry });
                    continue;
                }
                // Fully covered by newer windows → shadowed, skip. The
                // accepted set is non-overlapping, so walking it in y0
                // order measures coverage exactly.
                let mut ranges: Vec<(u64, u64)> = accepted
                    .iter()
                    .map(|a| (a.entry.y0, a.entry.y0 + a.entry.lines))
                    .collect();
                ranges.sort_unstable();
                let mut need = lo;
                for (a0, a1) in ranges {
                    if a0 <= need && need < a1 {
                        need = a1;
                    }
                    if need >= hi {
                        break;
                    }
                }
                if need < hi {
                    return Err(PdfflowError::Format(format!(
                        "run {}: slice {slice} window [{lo},{hi}) of {} is only partially \
                         shadowed by newer generations — the run mixes window grids; rerun \
                         the full slice (or rerun with the original window size), then compact",
                        self.key.label(),
                        self.segments[seg].file,
                    )));
                }
            }
        }
        accepted.sort_by_key(|a| a.entry.y0);
        Ok(accepted)
    }
}

/// The store catalog: geometry + every run's generational segment list.
#[derive(Clone, Debug)]
pub struct Catalog {
    pub dims: CubeDims,
    pub n_obs: usize,
    /// Next value of the monotone run-update sequence.
    pub next_seq: u64,
    pub runs: Vec<RunEntry>,
}

impl Catalog {
    pub fn new(dims: CubeDims, n_obs: usize) -> Catalog {
        Catalog {
            dims,
            n_obs,
            next_seq: 1,
            runs: Vec::new(),
        }
    }

    pub fn run(&self, key: &RunKey) -> Option<&RunEntry> {
        self.runs.iter().find(|r| &r.key == key)
    }

    /// The most recently updated run, if any.
    pub fn latest(&self) -> Option<&RunEntry> {
        self.runs.iter().max_by_key(|r| r.seq)
    }

    /// Resolve a run selector: `None` / `"latest"` → most recently
    /// updated run; otherwise the most recently updated run whose
    /// `run_id` matches. Every failure names what exists, so a typo'd
    /// `--run` is diagnosable from the error alone.
    pub fn select(&self, selector: Option<&str>) -> Result<&RunEntry> {
        let known = || {
            let mut ids: Vec<String> = self.runs.iter().map(|r| r.key.label()).collect();
            ids.sort();
            ids.join(", ")
        };
        match selector {
            None | Some("latest") => self.latest().ok_or_else(|| {
                PdfflowError::InvalidArg("store catalog holds no runs yet".into())
            }),
            Some(id) => self
                .runs
                .iter()
                .filter(|r| r.key.run_id == id)
                .max_by_key(|r| r.seq)
                .ok_or_else(|| {
                    PdfflowError::InvalidArg(format!(
                        "no run with id {id:?} in store (have: {})",
                        known()
                    ))
                }),
        }
    }

    /// Register a finished segment under its run (created on first
    /// write) and mark the run as the store's most recent.
    pub fn add_segment(&mut self, meta: SegmentMeta) {
        let key = RunKey::new(&meta.method, meta.types, &meta.run);
        let seq = self.next_seq;
        self.next_seq += 1;
        match self.runs.iter_mut().find(|r| r.key == key) {
            Some(run) => {
                run.seq = seq;
                run.segments.push(meta);
            }
            None => self.runs.push(RunEntry {
                key,
                seq,
                segments: vec![meta],
            }),
        }
    }

    /// Replace a run's whole segment list (compaction's publish step)
    /// and bump its recency.
    pub fn replace_run_segments(&mut self, key: &RunKey, segments: Vec<SegmentMeta>) -> Result<()> {
        let seq = self.next_seq;
        let run = self
            .runs
            .iter_mut()
            .find(|r| &r.key == key)
            .ok_or_else(|| {
                PdfflowError::InvalidArg(format!("run {} not in catalog", key.label()))
            })?;
        run.seq = seq;
        run.segments = segments;
        self.next_seq += 1;
        Ok(())
    }

    /// Every segment file any run references (orphan detection).
    pub fn referenced_files(&self) -> HashSet<String> {
        self.runs
            .iter()
            .flat_map(|r| r.segments.iter().map(|s| s.file.clone()))
            .collect()
    }

    fn body_json(&self) -> Json {
        let runs: Vec<Json> = self
            .runs
            .iter()
            .map(|r| {
                let segs: Vec<Json> = r
                    .segments
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("file", Json::Str(s.file.clone())),
                            ("slice", Json::Num(s.slice as f64)),
                            ("gen", Json::Num(s.gen as f64)),
                            ("windows", Json::Num(s.n_windows as f64)),
                            ("records", Json::Num(s.n_records as f64)),
                            ("bytes", Json::Num(s.bytes as f64)),
                            ("checksum", Json::Str(format!("{:016x}", s.checksum))),
                            (
                                "cover",
                                Json::Arr(
                                    s.cover
                                        .iter()
                                        .map(|&(lo, hi)| {
                                            Json::Arr(vec![
                                                Json::Num(lo as f64),
                                                Json::Num(hi as f64),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("id", Json::Str(r.key.run_id.clone())),
                    ("method", Json::Str(r.key.method.clone())),
                    ("types", Json::Num(r.key.types as f64)),
                    ("seq", Json::Num(r.seq as f64)),
                    ("segments", Json::Arr(segs)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(CATALOG_VERSION as f64)),
            (
                "dims",
                Json::Arr(vec![
                    Json::Num(self.dims.nx as f64),
                    Json::Num(self.dims.ny as f64),
                    Json::Num(self.dims.nz as f64),
                ]),
            ),
            ("n_obs", Json::Num(self.n_obs as f64)),
            ("next_seq", Json::Num(self.next_seq as f64)),
            ("runs", Json::Arr(runs)),
        ])
    }

    /// Atomic swap with a self-checksum: serialize the body, checksum
    /// it, write `CATALOG.json.tmp`, rename over `CATALOG.json`. A
    /// crash at any point leaves either the old catalog or the new one,
    /// never a torn file — the publish point of every write and every
    /// compaction.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let body = self.body_json();
        let body_text = body.to_string();
        let sum = fnv64(body_text.as_bytes());
        let doc = Json::obj(vec![
            ("body", body),
            ("checksum", Json::Str(format!("{sum:016x}"))),
        ]);
        let tmp = dir.join(format!("{CATALOG_NAME}.tmp"));
        let text = doc.to_string();
        crate::fault::retry("catalog.save", || {
            crate::fault::check("catalog.save")?;
            std::fs::write(&tmp, &text)?;
            std::fs::rename(&tmp, dir.join(CATALOG_NAME))?;
            Ok(())
        })
    }

    /// True when `dir` holds a catalog file.
    pub fn exists(dir: &Path) -> bool {
        dir.join(CATALOG_NAME).exists()
    }

    /// Load and verify the self-checksum; any mismatch is a hard error —
    /// a store with a broken catalog must not serve queries. A
    /// directory holding only the pre-generational `MANIFEST.json` gets
    /// a migration error, not a bare file-not-found.
    pub fn load(dir: &Path) -> Result<Catalog> {
        let path = dir.join(CATALOG_NAME);
        if !path.exists() && dir.join(LEGACY_MANIFEST_NAME).exists() {
            return Err(PdfflowError::Format(format!(
                "{} holds a legacy manifest-format store ({LEGACY_MANIFEST_NAME}, \
                 pre-generational catalog); re-persist the runs into a fresh store \
                 directory",
                dir.display()
            )));
        }
        let text = crate::fault::retry("catalog.load", || {
            crate::fault::check("catalog.load")?;
            Ok(std::fs::read_to_string(&path)?)
        })?;
        let doc = Json::parse(&text)
            .map_err(|e| PdfflowError::Format(format!("{}: {e}", path.display())))?;
        let bad = |what: &str| PdfflowError::Format(format!("{}: {what}", path.display()));
        let body = doc.get("body").ok_or_else(|| bad("missing body"))?;
        let want = doc
            .get("checksum")
            .and_then(|c| c.as_str())
            .and_then(parse_hex64)
            .ok_or_else(|| bad("missing checksum"))?;
        let got = fnv64(body.to_string().as_bytes());
        if got != want {
            return Err(bad(&format!(
                "catalog checksum mismatch (stored {want:016x}, computed {got:016x})"
            )));
        }
        let version = body
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("missing version"))?;
        if version != CATALOG_VERSION as usize {
            return Err(bad(&format!("unsupported catalog version {version}")));
        }
        let dims_arr = body
            .get("dims")
            .and_then(|d| d.as_arr())
            .ok_or_else(|| bad("missing dims"))?;
        if dims_arr.len() != 3 {
            return Err(bad("dims must have 3 entries"));
        }
        let dim = |i: usize| dims_arr[i].as_usize().ok_or_else(|| bad("bad dims entry"));
        let dims = CubeDims::new(dim(0)?, dim(1)?, dim(2)?);
        let n_obs = body
            .get("n_obs")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("missing n_obs"))?;
        let next_seq = body
            .get("next_seq")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("missing next_seq"))? as u64;
        let mut runs = Vec::new();
        for r in body
            .get("runs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing runs"))?
        {
            let run_id = r
                .get("id")
                .and_then(|v| v.as_str())
                .ok_or_else(|| bad("run missing id"))?
                .to_string();
            let method = r
                .get("method")
                .and_then(|v| v.as_str())
                .ok_or_else(|| bad("run missing method"))?
                .to_string();
            let types = r
                .get("types")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad("run missing types"))?;
            let seq = r
                .get("seq")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| bad("run missing seq"))? as u64;
            let mut segments = Vec::new();
            for s in r
                .get("segments")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| bad("run missing segments"))?
            {
                let field = |k: &str| s.get(k).and_then(|v| v.as_usize());
                segments.push(SegmentMeta {
                    file: s
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| bad("segment missing file"))?
                        .to_string(),
                    slice: field("slice").ok_or_else(|| bad("segment missing slice"))?,
                    method: method.clone(),
                    types,
                    run: run_id.clone(),
                    gen: field("gen").ok_or_else(|| bad("segment missing gen"))?,
                    n_windows: field("windows").ok_or_else(|| bad("segment missing windows"))?,
                    n_records: field("records").ok_or_else(|| bad("segment missing records"))?
                        as u64,
                    bytes: field("bytes").ok_or_else(|| bad("segment missing bytes"))? as u64,
                    checksum: s
                        .get("checksum")
                        .and_then(|v| v.as_str())
                        .and_then(parse_hex64)
                        .ok_or_else(|| bad("segment missing checksum"))?,
                    cover: {
                        let mut cover = Vec::new();
                        for c in s
                            .get("cover")
                            .and_then(|v| v.as_arr())
                            .ok_or_else(|| bad("segment missing cover"))?
                        {
                            let pair = c
                                .as_arr()
                                .filter(|p| p.len() == 2)
                                .ok_or_else(|| bad("cover range is not [start,end]"))?;
                            let range = |i: usize| {
                                pair[i]
                                    .as_usize()
                                    .map(|v| v as u64)
                                    .ok_or_else(|| bad("bad cover bound"))
                            };
                            cover.push((range(0)?, range(1)?));
                        }
                        cover
                    },
                });
            }
            runs.push(RunEntry {
                key: RunKey {
                    method,
                    types,
                    run_id,
                },
                seq,
                segments,
            });
        }
        Ok(Catalog {
            dims,
            n_obs,
            next_seq,
            runs,
        })
    }
}

fn parse_hex64(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(slice: usize, run: &str, gen: usize, file: &str) -> SegmentMeta {
        SegmentMeta {
            file: file.into(),
            slice,
            method: "baseline".into(),
            types: 4,
            run: run.into(),
            gen,
            n_windows: 2,
            n_records: 64,
            bytes: 1800,
            checksum: 0x1234_5678_9abc_def0,
            cover: vec![(0, 8)],
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("pdfflow-cat-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_and_tamper_detection() {
        let dir = tmp("rt");
        let mut c = Catalog::new(CubeDims::new(16, 12, 8), 100);
        c.add_segment(meta(1, "a", 0, "slice1_baseline_4_a_g0.seg"));
        c.add_segment(meta(1, "a", 1, "slice1_baseline_4_a_g1.seg"));
        c.add_segment(meta(2, "b", 0, "slice2_baseline_4_b_g0.seg"));
        c.save(&dir).unwrap();
        let back = Catalog::load(&dir).unwrap();
        assert_eq!(back.dims, c.dims);
        assert_eq!(back.n_obs, 100);
        assert_eq!(back.next_seq, c.next_seq);
        assert_eq!(back.runs.len(), 2);
        let a = back.run(&RunKey::new("baseline", 4, "a")).unwrap();
        assert_eq!(a.segments, c.runs[0].segments);
        assert_eq!(a.max_gen(), Some(1));
        assert_eq!(a.next_gen_for_slice(1), 2);
        assert_eq!(a.next_gen_for_slice(5), 0);
        // Latest is run "b" (added last).
        assert_eq!(back.latest().unwrap().key.run_id, "b");
        assert_eq!(back.select(Some("a")).unwrap().key.run_id, "a");
        assert!(back.select(Some("zzz")).is_err());
        // Tamper inside the body: the self-checksum must reject it.
        let path = dir.join(CATALOG_NAME);
        let text = std::fs::read_to_string(&path).unwrap();
        let tampered = text.replacen("\"slice\":1", "\"slice\":3", 1);
        assert_ne!(text, tampered);
        std::fs::write(&path, tampered).unwrap();
        assert!(Catalog::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resolution_prefers_newest_generation_per_window() {
        let mut c = Catalog::new(CubeDims::new(4, 8, 2), 10);
        // gen0 covers lines 0..8 in two windows; gen1 rewrites lines 4..8.
        c.add_segment(meta(0, "a", 0, "g0.seg"));
        c.add_segment(meta(0, "a", 1, "g1.seg"));
        let run = c.run(&RunKey::new("baseline", 4, "a")).unwrap();
        let windows = |seg: usize| -> Vec<WindowEntry> {
            match run.segments[seg].gen {
                0 => vec![
                    WindowEntry { y0: 0, lines: 4, offset: 8, n_records: 16, checksum: 0 },
                    WindowEntry { y0: 4, lines: 4, offset: 456, n_records: 16, checksum: 0 },
                ],
                _ => vec![WindowEntry { y0: 4, lines: 4, offset: 8, n_records: 16, checksum: 0 }],
            }
        };
        let resolved = run.resolve_slice(0, windows).unwrap();
        assert_eq!(resolved.len(), 2);
        // Lines 0..4 come from gen0, lines 4..8 from gen1.
        assert_eq!(resolved[0].entry.y0, 0);
        assert_eq!(run.segments[resolved[0].seg].gen, 0);
        assert_eq!(resolved[1].entry.y0, 4);
        assert_eq!(run.segments[resolved[1].seg].gen, 1);
    }

    #[test]
    fn misaligned_generations_are_an_error_not_silent_loss() {
        // gen0 window [0,8); gen1 rewrote only [0,6) with a different
        // grid. Whole-window shadowing would drop gen0's lines 6..8 —
        // resolution must refuse instead.
        let mut c = Catalog::new(CubeDims::new(4, 8, 2), 10);
        c.add_segment(meta(0, "a", 0, "g0.seg"));
        c.add_segment(meta(0, "a", 1, "g1.seg"));
        let run = c.run(&RunKey::new("baseline", 4, "a")).unwrap();
        let windows = |seg: usize| -> Vec<WindowEntry> {
            match run.segments[seg].gen {
                0 => vec![WindowEntry { y0: 0, lines: 8, offset: 8, n_records: 32, checksum: 0 }],
                _ => vec![WindowEntry { y0: 0, lines: 6, offset: 8, n_records: 24, checksum: 0 }],
            }
        };
        let err = run.resolve_slice(0, windows).unwrap_err();
        assert!(
            err.to_string().contains("partially shadowed"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn run_id_validation() {
        assert!(validate_run_id("default").is_ok());
        assert!(validate_run_id("exp-2.1_b").is_ok());
        assert!(validate_run_id("").is_err());
        assert!(validate_run_id("a/b").is_err());
        assert!(validate_run_id("latest").is_err(), "reserved selector id");
        assert!(validate_run_id(&"x".repeat(65)).is_err());
    }
}
