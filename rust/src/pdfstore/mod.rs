//! pdfstore: the persisted fitted-PDF store and its query engine.
//!
//! The paper's pipeline ends with "persist the PDFs of all points"
//! (Algorithm 1 line 11) — this subsystem is what makes that output
//! *servable*. The write path streams each slice's fit outcomes into a
//! per-slice **segment file** of fixed-width records in window order,
//! with a footer index (window → byte range) so any point or region is
//! reachable with one positioned read. Segments are organized by a
//! **generational run [`catalog`]** (`CATALOG.json`, checksummed,
//! swapped atomically): every run `(method, types, run_id)` owns its
//! own immutable segment files, reruns append new *generations* instead
//! of clobbering, and a cold process reopens any run with no data
//! rescan — the partition-local independence the Random Sample
//! Partition data model argues for (Salloum et al., arXiv 1712.04146).
//! [`compact`] rewrites a run's resolved view into dense, window-sorted
//! segments and retires superseded generations, query results
//! bit-identical. The read path ([`QueryEngine`]) serves point lookups,
//! rectangular region scans and analytical queries (density / CDF /
//! quantile via [`crate::stats`]) through a sharded LRU block cache,
//! fanned out as executor stages on the shared
//! [`crate::runtime::hostpool`] budget; [`crate::serve`] puts an
//! admission-controlled front door on top.
//!
//! On-disk layout of a store directory:
//!
//! ```text
//! store/
//!   CATALOG.json                            checksummed run catalog
//!   slice2_baseline_4_default_g0.seg        slice 2, run default/baseline/4, generation 0
//!   slice2_baseline_4_default_g1.seg        ... a rerun appended generation 1
//!   slice2_grouping_4_exp1_g0.seg           a different run: separate files
//!   ...
//! ```
//!
//! Segment file layout (all integers little-endian):
//!
//! ```text
//! [magic "PDFS"][version u32]                      8-byte header
//! [record x n]                                     28-byte records, window order
//! [footer: per window y0 u64, lines u64,
//!          offset u64, n_records u64,
//!          payload checksum u64]                   40 bytes per window
//! [footer_off u64][n_windows u64]
//! [checksum u64][magic "SFTR"]                     trailer
//! ```
//!
//! The trailer checksum is FNV-64 over every byte before the checksum
//! field, so corruption anywhere in the payload or index is detectable
//! ([`PdfStore::verify`]); each footer entry additionally carries an
//! FNV-64 of its own window payload, validated on every
//! `read_window`, so the query path catches bit rot the moment it is
//! read. Truncation is caught at open time against the catalog's byte
//! count, and the catalog carries its own self-checksum.
//!
//! A segment that fails these checks is **quarantined** rather than
//! fatal: the run re-resolves without it, newest-generation-first, and
//! keeps serving as long as the surviving generations still cover
//! every line the run ever covered (provable from the per-segment
//! `cover` ranges persisted in the catalog). Slices whose coverage is
//! lost become typed errors; `pdfstore::scrub` scans, reports, and —
//! with repair — rewrites salvageable runs from the surviving
//! generations.

pub mod catalog;
pub mod compact;
pub mod query;
pub mod scrub;
pub mod segment;

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, RwLock};

use crate::cube::{CubeDims, PointId};
use crate::stats::{DistType, FitResult};
use crate::telemetry::{self, Registry};
use crate::{PdfflowError, Result};

pub use catalog::{
    validate_run_id, Catalog, ResolvedWindow, RunEntry, RunKey, CATALOG_NAME, DEFAULT_RUN_ID,
    LEGACY_MANIFEST_NAME,
};
pub use compact::{compact_run, CompactReport};
pub use query::{
    CacheMeters, QueryEngine, QueryOptions, ReadPath, RegionQuery, RegionSummary, ERROR_HIST_BINS,
};
pub use scrub::{scrub_store, ScrubReport, ScrubRun, ScrubSegment};
pub use segment::{SegmentMeta, SegmentReader, SegmentWriter, WindowEntry};

/// Fixed record width: point id u64 + type u32 + error f32 + 3 param f32.
pub const REC_LEN: usize = 28;
/// Segment format version (v2: 40-byte footer entries carrying
/// per-window payload checksums).
pub const FORMAT_VERSION: u32 = 2;
/// Counter bumped once per segment quarantined in this process.
pub const QUARANTINED: &str = "store.quarantined_segments";

/// Streaming FNV-1a 64-bit checksum (offline crc substitute; the store
/// needs tamper/corruption detection, not cryptographic strength).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf29ce484222325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.0 = h;
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot FNV-64 of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// One persisted fitted PDF: the paper's per-point key-value output.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PdfRecord {
    pub point: PointId,
    pub dist: DistType,
    pub error: f32,
    pub params: [f32; 3],
}

impl PdfRecord {
    /// Encode into the fixed 28-byte wire form (identical to the legacy
    /// flat `.pdfout` row, so both persist paths stay bit-compatible).
    pub fn encode(&self, out: &mut [u8; REC_LEN]) {
        out[0..8].copy_from_slice(&self.point.0.to_le_bytes());
        out[8..12].copy_from_slice(&(self.dist.id() as u32).to_le_bytes());
        out[12..16].copy_from_slice(&self.error.to_le_bytes());
        for (i, p) in self.params.iter().enumerate() {
            out[16 + 4 * i..20 + 4 * i].copy_from_slice(&p.to_le_bytes());
        }
    }

    /// Decode one record from the first `REC_LEN` bytes of `b`.
    pub fn decode(b: &[u8]) -> Result<PdfRecord> {
        if b.len() < REC_LEN {
            return Err(PdfflowError::Format(format!(
                "pdf record needs {REC_LEN} bytes, got {}",
                b.len()
            )));
        }
        let u32_at = |o: usize| u32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let f32_at = |o: usize| f32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let type_id = u32_at(8) as usize;
        let dist = DistType::from_id(type_id).ok_or_else(|| {
            PdfflowError::Format(format!("pdf record: unknown type id {type_id}"))
        })?;
        Ok(PdfRecord {
            point: PointId(u64::from_le_bytes(b[0..8].try_into().unwrap())),
            dist,
            error: f32_at(12),
            params: [f32_at(16), f32_at(20), f32_at(24)],
        })
    }

    /// View as a [`FitResult`] for the `stats`/`density` evaluators.
    pub fn fit(&self) -> FitResult {
        FitResult {
            dist: self.dist,
            params: [
                self.params[0] as f64,
                self.params[1] as f64,
                self.params[2] as f64,
            ],
            error: self.error as f64,
        }
    }
}

/// Run selection when opening a store for reads.
#[derive(Clone, Copy, Debug)]
pub enum RunSelector<'a> {
    /// The most recently updated run.
    Latest,
    /// The most recently updated run with this `run_id`.
    Id(&'a str),
    /// An exact `(method, types, run_id)` run.
    Key(&'a RunKey),
}

impl<'a> RunSelector<'a> {
    /// CLI form: `None`/`"latest"` → latest, anything else → by id.
    pub fn from_opt(opt: Option<&'a str>) -> RunSelector<'a> {
        match opt {
            None | Some("latest") => RunSelector::Latest,
            Some(id) => RunSelector::Id(id),
        }
    }
}

/// Write side of a store: the pipeline's persist sink. Segments are
/// opened per slice run; the catalog is rewritten (atomic swap) after
/// each finished segment, so the store on disk is always openable and
/// no file is ever referenced before it is complete.
///
/// `add_segment` re-reads the on-disk catalog before every swap, so a
/// compaction (or another writer) that published between this writer's
/// segments is preserved rather than overwritten with a stale snapshot
/// — the catalog never ends up referencing files a racing compaction
/// already unlinked. True simultaneous load-modify-save races still
/// resolve last-swap-wins (crash-safe, possibly dropping the slower
/// writer's entry), so one live `StoreWriter` per directory remains
/// the supported mode.
pub struct StoreWriter {
    dir: PathBuf,
    catalog: Catalog,
}

impl StoreWriter {
    /// Create the store directory (or attach to an existing one, checking
    /// that its geometry matches).
    pub fn create(dir: impl AsRef<Path>, dims: CubeDims, n_obs: usize) -> Result<StoreWriter> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        if !Catalog::exists(&dir) && dir.join(catalog::LEGACY_MANIFEST_NAME).exists() {
            // Starting a fresh catalog next to manifest-era segments
            // would silently orphan them; surface the format change.
            return Err(PdfflowError::Format(format!(
                "{} holds a legacy manifest-format store; persist into a fresh directory",
                dir.display()
            )));
        }
        let catalog = if Catalog::exists(&dir) {
            let c = Catalog::load(&dir)?;
            if c.dims != dims || c.n_obs != n_obs {
                return Err(PdfflowError::InvalidArg(format!(
                    "store at {} holds a {}x{}x{} cube with {} observations; \
                     refusing to mix in {}x{}x{} with {}",
                    dir.display(),
                    c.dims.nx,
                    c.dims.ny,
                    c.dims.nz,
                    c.n_obs,
                    dims.nx,
                    dims.ny,
                    dims.nz,
                    n_obs
                )));
            }
            c
        } else {
            let c = Catalog::new(dims, n_obs);
            c.save(&dir)?;
            c
        };
        Ok(StoreWriter { dir, catalog })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn catalog(&self) -> &Catalog {
        &self.catalog
    }

    /// Open a segment writer for one slice of a run. The generation is
    /// assigned here: one past the run's newest existing generation of
    /// this slice, so a rerun appends instead of overwriting.
    pub fn open_segment(&self, slice: usize, key: &RunKey) -> Result<SegmentWriter> {
        validate_run_id(&key.run_id)?;
        let gen = self
            .catalog
            .run(key)
            .map(|r| r.next_gen_for_slice(slice))
            .unwrap_or(0);
        SegmentWriter::create(&self.dir, slice, &key.method, key.types, &key.run_id, gen)
    }

    /// Register a finished segment under its run and persist the
    /// catalog (atomic swap — the publish point of the write). The
    /// on-disk catalog is re-read first so a compaction that published
    /// since this writer attached is carried forward, not clobbered.
    pub fn add_segment(&mut self, meta: SegmentMeta) -> Result<()> {
        if let Ok(fresh) = Catalog::load(&self.dir) {
            if fresh.dims == self.catalog.dims && fresh.n_obs == self.catalog.n_obs {
                self.catalog = fresh;
            }
        }
        self.catalog.add_segment(meta);
        self.catalog.save(&self.dir)
    }
}

/// One resolved, readable window of an open store: segment index (into
/// the open run's reader list) + window index + its footer entry.
pub type SlicePart = ResolvedWindow;

/// Merge `[start, end)` ranges into canonical form: sorted,
/// non-overlapping, non-adjacent, empties dropped. Two range sets
/// describe the same line set iff their canonical forms are equal —
/// the comparison the quarantine fallback uses to prove no line was
/// silently lost.
fn merge_ranges(mut v: Vec<(u64, u64)>) -> Vec<(u64, u64)> {
    v.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::new();
    for (s, e) in v {
        if s >= e {
            continue;
        }
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Resolution outcome of one slice under the current quarantine set.
#[derive(Clone, Debug)]
enum SliceState {
    /// Fully covered (possibly through older generations).
    Ok(Arc<Vec<SlicePart>>),
    /// Some lines the run once covered are no longer reachable; reads
    /// of this slice return this message as a typed `Format` error.
    Unresolvable(String),
}

/// Mutable resolution state of an open store: which segments are
/// quarantined, and the per-slice views resolved around them.
struct ResolveState {
    /// Segment indexes quarantined (open failures + read-time checksum
    /// failures).
    bad: BTreeSet<usize>,
    slices: HashMap<usize, SliceState>,
    /// Slices that resolve Ok but lean on older generations because a
    /// newer-generation segment is quarantined (the `degraded: true`
    /// serve surface).
    degraded: BTreeSet<usize>,
}

/// Verification outcome of one catalog segment.
#[derive(Clone, Debug)]
pub struct SegmentVerify {
    /// Index into the open run's segment list.
    pub idx: usize,
    pub file: String,
    pub slice: usize,
    pub gen: usize,
    /// `None` = checksums good; otherwise why the segment is bad.
    pub error: Option<String>,
}

/// Full-store verification report: one row per segment, never
/// aborted early.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub segments: Vec<SegmentVerify>,
}

impl VerifyReport {
    pub fn n_bad(&self) -> usize {
        self.segments.iter().filter(|s| s.error.is_some()).count()
    }

    pub fn all_ok(&self) -> bool {
        self.n_bad() == 0
    }

    /// One line per segment, `ok`/`BAD` prefixed — the CLI listing.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for s in &self.segments {
            match &s.error {
                None => out.push_str(&format!("ok  {} (slice {}, gen {})\n", s.file, s.slice, s.gen)),
                Some(e) => out.push_str(&format!(
                    "BAD {} (slice {}, gen {}): {e}\n",
                    s.file, s.slice, s.gen
                )),
            }
        }
        out
    }
}

/// Read side: one **run view** over the catalog. Opening selects a run
/// (latest or named), opens its segment readers — validating lengths,
/// magics and footer indexes, no payload rescan — and resolves every
/// slice to its newest-generation window set.
///
/// A segment that fails validation (at open, or later at read time via
/// a per-window checksum mismatch) is **quarantined**: the run
/// re-resolves without it, falling back newest-generation-first, and
/// the per-segment `cover` ranges in the catalog prove whether every
/// line the run ever covered is still reachable. Covered slices keep
/// serving (flagged degraded); slices with lost coverage become typed
/// errors. `open` fails only when coverage is already lost at open
/// time.
pub struct PdfStore {
    pub dir: PathBuf,
    pub catalog: Catalog,
    run_idx: usize,
    /// One slot per catalog segment; `Err` holds why open failed (the
    /// slot is auto-quarantined).
    segments: Vec<std::result::Result<SegmentReader, String>>,
    state: RwLock<ResolveState>,
    /// Bumped on every quarantine; readers key caches (spatial index,
    /// block cache retries) off it.
    epoch: AtomicU64,
}

impl PdfStore {
    /// Open the most recently updated run.
    pub fn open(dir: impl AsRef<Path>) -> Result<PdfStore> {
        Self::open_run(dir, RunSelector::Latest)
    }

    /// Open a specific run of the store. Fails if any slice's coverage
    /// is already unresolvable (e.g. the only copy of a window is
    /// corrupt); tolerates bad segments whose lines older generations
    /// still cover.
    pub fn open_run(dir: impl AsRef<Path>, sel: RunSelector) -> Result<PdfStore> {
        let store = Self::open_run_tolerant(dir, sel)?;
        let bad = store.unresolvable_slices();
        if let Some((z, why)) = bad.first() {
            return Err(PdfflowError::Format(format!(
                "store run {}: {} unresolvable slice(s); slice {z}: {why}",
                store.run_key().label(),
                bad.len()
            )));
        }
        Ok(store)
    }

    /// Open like [`Self::open_run`] but keep the store usable even when
    /// slices are unresolvable (reads of those slices return typed
    /// errors). The scrub path uses this to report and repair stores a
    /// strict open would reject.
    pub fn open_run_tolerant(dir: impl AsRef<Path>, sel: RunSelector) -> Result<PdfStore> {
        let dir = dir.as_ref().to_path_buf();
        let catalog = Catalog::load(&dir)?;
        let entry = match sel {
            RunSelector::Latest => catalog.select(None)?,
            RunSelector::Id(id) => catalog.select(Some(id))?,
            RunSelector::Key(key) => catalog.run(key).ok_or_else(|| {
                PdfflowError::InvalidArg(format!("no run {} in store", key.label()))
            })?,
        };
        let run_idx = catalog
            .runs
            .iter()
            .position(|r| r.key == entry.key)
            .expect("selected run is in the catalog");
        let run = &catalog.runs[run_idx];
        let mut segments = Vec::with_capacity(run.segments.len());
        let mut bad = BTreeSet::new();
        for (idx, meta) in run.segments.iter().enumerate() {
            match SegmentReader::open(&dir, meta) {
                Ok(r) => segments.push(Ok(r)),
                Err(e) => {
                    bad.insert(idx);
                    segments.push(Err(e.to_string()));
                }
            }
        }
        for &idx in &bad {
            note_quarantine(&run.segments[idx].file, segments[idx].as_ref().err());
        }
        let (slices, degraded) = resolve_all(run, &segments, &bad);
        Ok(PdfStore {
            dir,
            catalog,
            run_idx,
            segments,
            state: RwLock::new(ResolveState { bad, slices, degraded }),
            epoch: AtomicU64::new(0),
        })
    }

    /// The open run's catalog entry.
    pub fn run(&self) -> &RunEntry {
        &self.catalog.runs[self.run_idx]
    }

    /// The open run's identity.
    pub fn run_key(&self) -> &RunKey {
        &self.run().key
    }

    pub fn dims(&self) -> CubeDims {
        self.catalog.dims
    }

    pub fn n_obs(&self) -> usize {
        self.catalog.n_obs
    }

    /// Segment files of the open run (all generations).
    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    /// Records reachable through the resolved view (shadowed
    /// generations and unresolvable slices excluded).
    pub fn n_records(&self) -> u64 {
        let st = self.state.read().unwrap();
        st.slices
            .values()
            .filter_map(|s| match s {
                SliceState::Ok(parts) => Some(parts.iter().map(|p| p.entry.n_records).sum::<u64>()),
                SliceState::Unresolvable(_) => None,
            })
            .sum()
    }

    /// On-disk bytes of the open run's segments (all generations).
    pub fn total_bytes(&self) -> u64 {
        self.run().segments.iter().map(|s| s.bytes).sum()
    }

    /// Reader for segment `idx`; a typed error if the segment failed to
    /// open or has been quarantined.
    pub fn reader(&self, idx: usize) -> Result<&SegmentReader> {
        if self.state.read().unwrap().bad.contains(&idx) {
            let file = &self.run().segments[idx].file;
            return Err(PdfflowError::Format(format!("{file}: segment is quarantined")));
        }
        match &self.segments[idx] {
            Ok(r) => Ok(r),
            Err(e) => Err(PdfflowError::Format(e.clone())),
        }
    }

    /// Quarantine segment `idx` (idempotent; returns whether this call
    /// changed anything). Re-resolves every slice around the bad
    /// segment, bumps the store epoch, counts
    /// `store.quarantined_segments` and marks the flight recorder.
    pub fn quarantine_segment(&self, idx: usize, why: &str) -> bool {
        {
            let mut st = self.state.write().unwrap();
            if !st.bad.insert(idx) {
                return false;
            }
            let (slices, degraded) = resolve_all(self.run(), &self.segments, &st.bad);
            st.slices = slices;
            st.degraded = degraded;
        }
        self.epoch.fetch_add(1, Relaxed);
        note_quarantine(&self.run().segments[idx].file, Some(&why.to_string()));
        true
    }

    /// Monotone counter bumped on every quarantine; derived caches key
    /// off it.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Relaxed)
    }

    /// Identity stamp of the on-disk catalog generation this store was
    /// opened against. Catalog saves are atomic tmp+rename swaps, so
    /// every rerun / compaction / scrub repair publishes a *new inode*
    /// — hashing `(ino, mtime, mtime_nsec, len)` of `CATALOG.json`
    /// yields a value that changes whenever any of those paths swap the
    /// catalog out from under a long-lived reader. Serve-side result
    /// caches key entries off this (combined with [`Self::epoch`]) so
    /// stale answers are impossible across catalog swaps. Returns 0
    /// when the stat fails (treated as "always stale").
    pub fn catalog_stamp(&self) -> u64 {
        use std::os::unix::fs::MetadataExt;
        let Ok(md) = std::fs::metadata(self.dir.join(CATALOG_NAME)) else {
            return 0;
        };
        let mut h = Fnv64::new();
        h.update(&md.ino().to_le_bytes());
        h.update(&md.mtime().to_le_bytes());
        h.update(&md.mtime_nsec().to_le_bytes());
        h.update(&md.len().to_le_bytes());
        h.finish()
    }

    /// Segments currently quarantined (open failures included).
    pub fn n_quarantined(&self) -> usize {
        self.state.read().unwrap().bad.len()
    }

    /// True when any segment is quarantined — i.e. answers may be
    /// served through generation fallback.
    pub fn is_degraded(&self) -> bool {
        self.n_quarantined() > 0
    }

    /// True when any slice in `[z0, z1]` resolves through generation
    /// fallback around a quarantined segment.
    pub fn degraded_in(&self, z0: usize, z1: usize) -> bool {
        let st = self.state.read().unwrap();
        st.degraded.iter().any(|&z| z0 <= z && z <= z1)
    }

    /// The first unresolvable slice in `[z0, z1]`, with its reason.
    pub fn unresolvable_in(&self, z0: usize, z1: usize) -> Option<(usize, String)> {
        let st = self.state.read().unwrap();
        let mut hits: Vec<(usize, String)> = st
            .slices
            .iter()
            .filter(|(z, _)| z0 <= **z && **z <= z1)
            .filter_map(|(z, s)| match s {
                SliceState::Unresolvable(why) => Some((*z, why.clone())),
                SliceState::Ok(_) => None,
            })
            .collect();
        hits.sort_unstable_by_key(|(z, _)| *z);
        hits.into_iter().next()
    }

    /// Every unresolvable slice, ascending.
    pub fn unresolvable_slices(&self) -> Vec<(usize, String)> {
        let st = self.state.read().unwrap();
        let mut out: Vec<(usize, String)> = st
            .slices
            .iter()
            .filter_map(|(z, s)| match s {
                SliceState::Unresolvable(why) => Some((*z, why.clone())),
                SliceState::Ok(_) => None,
            })
            .collect();
        out.sort_unstable_by_key(|(z, _)| *z);
        out
    }

    /// Slices the open run serves, ascending (unresolvable included —
    /// reads of those yield typed errors).
    pub fn slices(&self) -> Vec<usize> {
        let st = self.state.read().unwrap();
        let mut out: Vec<usize> = st.slices.keys().copied().collect();
        out.sort_unstable();
        out
    }

    /// Resolved windows of slice `z`: `Ok(None)` if the slice was never
    /// persisted, a typed error if its coverage is unresolvable.
    pub fn slice_parts(&self, z: usize) -> Result<Option<Arc<Vec<SlicePart>>>> {
        let st = self.state.read().unwrap();
        match st.slices.get(&z) {
            None => Ok(None),
            Some(SliceState::Ok(parts)) => Ok(Some(parts.clone())),
            Some(SliceState::Unresolvable(why)) => Err(PdfflowError::Format(format!(
                "slice {z} is unresolvable: {why}"
            ))),
        }
    }

    /// Lenient variant of [`Self::slice_parts`]: unresolvable slices
    /// read as absent. For best-effort consumers (index builds) whose
    /// callers do their own strict pre-checks.
    pub fn resolved_parts(&self, z: usize) -> Option<Arc<Vec<SlicePart>>> {
        match self.state.read().unwrap().slices.get(&z) {
            Some(SliceState::Ok(parts)) => Some(parts.clone()),
            _ => None,
        }
    }

    /// The resolved window covering line `y` of slice `z`, if any.
    pub fn find_part(&self, z: usize, y: usize) -> Result<Option<SlicePart>> {
        let Some(parts) = self.slice_parts(z)? else {
            return Ok(None);
        };
        let y = y as u64;
        // Parts are sorted by y0 and non-overlapping.
        let idx = parts.partition_point(|p| p.entry.y0 <= y);
        if idx == 0 {
            return Ok(None);
        }
        let p = parts[idx - 1];
        Ok((y < p.entry.y0 + p.entry.lines).then_some(p))
    }

    /// True when the resolved view covers every line in `[y0, y1]` of
    /// slice `z` with no gap (store-backed training requires this).
    /// Unresolvable slices cover nothing.
    pub fn covers_lines(&self, z: usize, y0: usize, y1: usize) -> bool {
        let Some(parts) = self.resolved_parts(z) else {
            return false;
        };
        let mut next = y0 as u64;
        for p in parts.iter() {
            if p.entry.y0 > next {
                break; // gap
            }
            if p.entry.y0 + p.entry.lines > next {
                next = p.entry.y0 + p.entry.lines;
            }
            if next > y1 as u64 {
                return true;
            }
        }
        next > y1 as u64
    }

    /// Full-payload checksum verification of every catalog segment of
    /// the open run — never aborts early; one row per segment. Open
    /// failures and quarantines report their stored reason.
    pub fn verify_report(&self) -> VerifyReport {
        let quarantined: BTreeSet<usize> = self.state.read().unwrap().bad.clone();
        let mut report = VerifyReport::default();
        for (idx, meta) in self.run().segments.iter().enumerate() {
            let error = match &self.segments[idx] {
                Err(e) => Some(e.clone()),
                Ok(seg) => seg.verify().err().map(|e| e.to_string()).or_else(|| {
                    quarantined
                        .contains(&idx)
                        .then(|| "segment is quarantined".to_string())
                }),
            };
            report.segments.push(SegmentVerify {
                idx,
                file: meta.file.clone(),
                slice: meta.slice,
                gen: meta.gen,
                error,
            });
        }
        report
    }

    /// Full-store verification; `Err` carries the complete per-segment
    /// listing when anything failed.
    pub fn verify(&self) -> Result<()> {
        let report = self.verify_report();
        if report.all_ok() {
            Ok(())
        } else {
            Err(PdfflowError::Format(format!(
                "{} corrupt segment(s):\n{}",
                report.n_bad(),
                report.render()
            )))
        }
    }
}

/// Count + flight-mark one quarantined segment.
fn note_quarantine(file: &str, why: Option<&String>) {
    Registry::global().counter(QUARANTINED).inc();
    let detail = why.cloned().unwrap_or_default();
    telemetry::mark("store.quarantine", || format!("{file}: {detail}"));
}

/// Resolve every slice of `run` with the quarantined set excluded, and
/// prove per slice that the surviving generations still cover every
/// line the run ever covered (from the catalog `cover` ranges). Returns
/// the per-slice states plus the set of slices that lean on fallback.
fn resolve_all(
    run: &RunEntry,
    segments: &[std::result::Result<SegmentReader, String>],
    bad: &BTreeSet<usize>,
) -> (HashMap<usize, SliceState>, BTreeSet<usize>) {
    // Expected coverage per slice: union over ALL catalog segments
    // (healthy and bad alike) — newest-first shadowing means the run
    // served every line any generation covered.
    let mut expected: BTreeMap<usize, Vec<(u64, u64)>> = BTreeMap::new();
    for meta in &run.segments {
        expected.entry(meta.slice).or_default().extend(meta.cover.iter().copied());
    }
    let mut slices = HashMap::new();
    let mut degraded = BTreeSet::new();
    for z in run.slices() {
        let resolved = run.resolve_slice(z, |seg| {
            if bad.contains(&seg) {
                return Vec::new();
            }
            match &segments[seg] {
                Ok(r) => r.entries.clone(),
                Err(_) => Vec::new(),
            }
        });
        let state = match resolved {
            Err(e) => SliceState::Unresolvable(e.to_string()),
            Ok(parts) => {
                let want = merge_ranges(expected.remove(&z).unwrap_or_default());
                let got = merge_ranges(
                    parts
                        .iter()
                        .map(|p| (p.entry.y0, p.entry.y0 + p.entry.lines))
                        .collect(),
                );
                if want != got {
                    SliceState::Unresolvable(format!(
                        "coverage lost to quarantine: run covered lines {want:?}, survivors cover {got:?}"
                    ))
                } else {
                    let uses_bad_slice = run
                        .segments
                        .iter()
                        .enumerate()
                        .any(|(i, m)| m.slice == z && bad.contains(&i));
                    if uses_bad_slice {
                        degraded.insert(z);
                    }
                    SliceState::Ok(Arc::new(parts))
                }
            }
        };
        slices.insert(z, state);
    }
    (slices, degraded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_codec_roundtrip_exact_width() {
        let rec = PdfRecord {
            point: PointId(123_456_789_012),
            dist: DistType::Weibull,
            error: 0.125,
            params: [1.5, -2.25, 0.0],
        };
        let mut buf = [0u8; REC_LEN];
        rec.encode(&mut buf);
        let back = PdfRecord::decode(&buf).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn record_decode_rejects_bad_type_and_short_buffer() {
        let mut buf = [0u8; REC_LEN];
        PdfRecord {
            point: PointId(1),
            dist: DistType::Normal,
            error: 0.0,
            params: [0.0; 3],
        }
        .encode(&mut buf);
        buf[8] = 42; // type id out of range
        assert!(PdfRecord::decode(&buf).is_err());
        assert!(PdfRecord::decode(&buf[..REC_LEN - 1]).is_err());
    }

    #[test]
    fn fnv64_is_stable_and_sensitive() {
        let a = fnv64(b"pdfstore");
        assert_eq!(a, fnv64(b"pdfstore"));
        assert_ne!(a, fnv64(b"pdfstorf"));
        let mut streaming = Fnv64::new();
        streaming.update(b"pdf");
        streaming.update(b"store");
        assert_eq!(streaming.finish(), a);
    }

    #[test]
    fn store_writer_assigns_generations_and_refuses_geometry_mix() {
        let dir = std::env::temp_dir().join(format!("pdfflow-sw-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dims = CubeDims::new(4, 4, 2);
        let w = StoreWriter::create(&dir, dims, 50).unwrap();
        let key = RunKey::new("baseline", 4, "default");
        // Empty store: first segment of any slice is generation 0.
        let sw = w.open_segment(1, &key).unwrap();
        drop(sw); // abandoned tmp file; never registered
        assert!(StoreWriter::create(&dir, CubeDims::new(5, 4, 2), 50).is_err());
        assert!(StoreWriter::create(&dir, dims, 51).is_err());
        // Invalid run ids are rejected before any file is created.
        assert!(w.open_segment(1, &RunKey::new("baseline", 4, "a/b")).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
