//! pdfstore: the persisted fitted-PDF store and its query engine.
//!
//! The paper's pipeline ends with "persist the PDFs of all points"
//! (Algorithm 1 line 11) — this subsystem is what makes that output
//! *servable*. The write path streams each slice's fit outcomes into a
//! per-slice **segment file** of fixed-width records in window order,
//! with a footer index (window → byte range) so any point or region is
//! reachable with one positioned read; a **checksummed manifest**
//! (JSON, FNV-64 self-checksum) makes the store self-describing, so a
//! cold process reopens it with no data rescan — the same
//! partition-local independence the Random Sample Partition data model
//! argues for (Salloum et al., arXiv 1712.04146). The read path
//! ([`QueryEngine`]) serves point lookups, rectangular region scans and
//! analytical queries (density / CDF / quantile via [`crate::stats`])
//! through a sharded LRU block cache, fanned out as executor stages on
//! the shared [`crate::runtime::hostpool`] budget.
//!
//! On-disk layout of a store directory:
//!
//! ```text
//! store/
//!   MANIFEST.json                 checksummed manifest (see StoreManifest)
//!   slice201_baseline_4.seg       one segment per persisted slice run
//!   ...
//! ```
//!
//! Segment file layout (all integers little-endian):
//!
//! ```text
//! [magic "PDFS"][version u32]                      8-byte header
//! [record x n]                                     28-byte records, window order
//! [footer: per window y0 u64, lines u64,
//!          offset u64, n_records u64]              32 bytes per window
//! [footer_off u64][n_windows u64]
//! [checksum u64][magic "SFTR"]                     trailer
//! ```
//!
//! The trailer checksum is FNV-64 over every byte before the checksum
//! field, so corruption anywhere in the payload or index is detectable
//! ([`PdfStore::verify`]); truncation is caught at open time against the
//! manifest's byte count.

pub mod query;
pub mod segment;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::cube::{CubeDims, PointId};
use crate::stats::{DistType, FitResult};
use crate::util::json::Json;
use crate::{PdfflowError, Result};

pub use query::{CacheMeters, QueryEngine, QueryOptions, RegionQuery, RegionSummary};
pub use segment::{SegmentMeta, SegmentReader, SegmentWriter, WindowEntry};

/// Fixed record width: point id u64 + type u32 + error f32 + 3 param f32.
pub const REC_LEN: usize = 28;
/// Manifest file name inside a store directory.
pub const MANIFEST_NAME: &str = "MANIFEST.json";
/// Manifest/segment format version.
pub const FORMAT_VERSION: u32 = 1;

/// Streaming FNV-1a 64-bit checksum (offline crc substitute; the store
/// needs tamper/corruption detection, not cryptographic strength).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf29ce484222325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut h = self.0;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        self.0 = h;
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot FNV-64 of a byte slice.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// One persisted fitted PDF: the paper's per-point key-value output.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PdfRecord {
    pub point: PointId,
    pub dist: DistType,
    pub error: f32,
    pub params: [f32; 3],
}

impl PdfRecord {
    /// Encode into the fixed 28-byte wire form (identical to the legacy
    /// flat `.pdfout` row, so both persist paths stay bit-compatible).
    pub fn encode(&self, out: &mut [u8; REC_LEN]) {
        out[0..8].copy_from_slice(&self.point.0.to_le_bytes());
        out[8..12].copy_from_slice(&(self.dist.id() as u32).to_le_bytes());
        out[12..16].copy_from_slice(&self.error.to_le_bytes());
        for (i, p) in self.params.iter().enumerate() {
            out[16 + 4 * i..20 + 4 * i].copy_from_slice(&p.to_le_bytes());
        }
    }

    /// Decode one record from the first `REC_LEN` bytes of `b`.
    pub fn decode(b: &[u8]) -> Result<PdfRecord> {
        if b.len() < REC_LEN {
            return Err(PdfflowError::Format(format!(
                "pdf record needs {REC_LEN} bytes, got {}",
                b.len()
            )));
        }
        let u32_at = |o: usize| u32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let f32_at = |o: usize| f32::from_le_bytes(b[o..o + 4].try_into().unwrap());
        let type_id = u32_at(8) as usize;
        let dist = DistType::from_id(type_id).ok_or_else(|| {
            PdfflowError::Format(format!("pdf record: unknown type id {type_id}"))
        })?;
        Ok(PdfRecord {
            point: PointId(u64::from_le_bytes(b[0..8].try_into().unwrap())),
            dist,
            error: f32_at(12),
            params: [f32_at(16), f32_at(20), f32_at(24)],
        })
    }

    /// View as a [`FitResult`] for the `stats`/`density` evaluators.
    pub fn fit(&self) -> FitResult {
        FitResult {
            dist: self.dist,
            params: [
                self.params[0] as f64,
                self.params[1] as f64,
                self.params[2] as f64,
            ],
            error: self.error as f64,
        }
    }
}

/// Self-describing store metadata: cube geometry plus one entry per
/// segment. Serialized as `{"body": {...}, "checksum": "<fnv64 hex>"}`
/// where the checksum covers the serialized body byte-for-byte.
#[derive(Clone, Debug)]
pub struct StoreManifest {
    pub dims: CubeDims,
    pub n_obs: usize,
    pub segments: Vec<SegmentMeta>,
}

impl StoreManifest {
    fn body_json(&self) -> Json {
        let segs: Vec<Json> = self
            .segments
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("file", Json::Str(s.file.clone())),
                    ("slice", Json::Num(s.slice as f64)),
                    ("method", Json::Str(s.method.clone())),
                    ("types", Json::Num(s.types as f64)),
                    ("windows", Json::Num(s.n_windows as f64)),
                    ("records", Json::Num(s.n_records as f64)),
                    ("bytes", Json::Num(s.bytes as f64)),
                    ("checksum", Json::Str(format!("{:016x}", s.checksum))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::Num(FORMAT_VERSION as f64)),
            (
                "dims",
                Json::Arr(vec![
                    Json::Num(self.dims.nx as f64),
                    Json::Num(self.dims.ny as f64),
                    Json::Num(self.dims.nz as f64),
                ]),
            ),
            ("n_obs", Json::Num(self.n_obs as f64)),
            ("segments", Json::Arr(segs)),
        ])
    }

    /// Write atomically (temp file + rename) with a self-checksum.
    pub fn save(&self, dir: &Path) -> Result<()> {
        let body = self.body_json();
        let body_text = body.to_string();
        let sum = fnv64(body_text.as_bytes());
        let doc = Json::obj(vec![
            ("body", body),
            ("checksum", Json::Str(format!("{sum:016x}"))),
        ]);
        let tmp = dir.join(format!("{MANIFEST_NAME}.tmp"));
        std::fs::write(&tmp, doc.to_string())?;
        std::fs::rename(&tmp, dir.join(MANIFEST_NAME))?;
        Ok(())
    }

    /// Load and verify the self-checksum; any mismatch is a hard error —
    /// a store with a broken manifest must not serve queries.
    pub fn load(dir: &Path) -> Result<StoreManifest> {
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path)?;
        let doc = Json::parse(&text)
            .map_err(|e| PdfflowError::Format(format!("{}: {e}", path.display())))?;
        let bad = |what: &str| PdfflowError::Format(format!("{}: {what}", path.display()));
        let body = doc.get("body").ok_or_else(|| bad("missing body"))?;
        let want = doc
            .get("checksum")
            .and_then(|c| c.as_str())
            .and_then(parse_hex64)
            .ok_or_else(|| bad("missing checksum"))?;
        let got = fnv64(body.to_string().as_bytes());
        if got != want {
            return Err(bad(&format!(
                "manifest checksum mismatch (stored {want:016x}, computed {got:016x})"
            )));
        }
        let version = body
            .get("version")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("missing version"))?;
        if version != FORMAT_VERSION as usize {
            return Err(bad(&format!("unsupported store version {version}")));
        }
        let dims_arr = body
            .get("dims")
            .and_then(|d| d.as_arr())
            .ok_or_else(|| bad("missing dims"))?;
        if dims_arr.len() != 3 {
            return Err(bad("dims must have 3 entries"));
        }
        let dim = |i: usize| dims_arr[i].as_usize().ok_or_else(|| bad("bad dims entry"));
        let dims = CubeDims::new(dim(0)?, dim(1)?, dim(2)?);
        let n_obs = body
            .get("n_obs")
            .and_then(|v| v.as_usize())
            .ok_or_else(|| bad("missing n_obs"))?;
        let mut segments = Vec::new();
        for s in body
            .get("segments")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing segments"))?
        {
            let field = |k: &str| s.get(k).and_then(|v| v.as_usize());
            segments.push(SegmentMeta {
                file: s
                    .get("file")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("segment missing file"))?
                    .to_string(),
                slice: field("slice").ok_or_else(|| bad("segment missing slice"))?,
                method: s
                    .get("method")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| bad("segment missing method"))?
                    .to_string(),
                types: field("types").ok_or_else(|| bad("segment missing types"))?,
                n_windows: field("windows").ok_or_else(|| bad("segment missing windows"))?,
                n_records: field("records").ok_or_else(|| bad("segment missing records"))?
                    as u64,
                bytes: field("bytes").ok_or_else(|| bad("segment missing bytes"))? as u64,
                checksum: s
                    .get("checksum")
                    .and_then(|v| v.as_str())
                    .and_then(parse_hex64)
                    .ok_or_else(|| bad("segment missing checksum"))?,
            });
        }
        Ok(StoreManifest {
            dims,
            n_obs,
            segments,
        })
    }
}

fn parse_hex64(s: &str) -> Option<u64> {
    u64::from_str_radix(s, 16).ok()
}

/// Write side of a store: the pipeline's persist sink. Segments are
/// opened per slice run; the manifest is rewritten (atomically) after
/// each finished segment, so the store on disk is always openable.
pub struct StoreWriter {
    dir: PathBuf,
    manifest: StoreManifest,
}

impl StoreWriter {
    /// Create the store directory (or attach to an existing one, checking
    /// that its geometry matches).
    pub fn create(dir: impl AsRef<Path>, dims: CubeDims, n_obs: usize) -> Result<StoreWriter> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        let manifest = if dir.join(MANIFEST_NAME).exists() {
            let m = StoreManifest::load(&dir)?;
            if m.dims != dims || m.n_obs != n_obs {
                return Err(PdfflowError::InvalidArg(format!(
                    "store at {} holds a {}x{}x{} cube with {} observations; \
                     refusing to mix in {}x{}x{} with {}",
                    dir.display(),
                    m.dims.nx,
                    m.dims.ny,
                    m.dims.nz,
                    m.n_obs,
                    dims.nx,
                    dims.ny,
                    dims.nz,
                    n_obs
                )));
            }
            m
        } else {
            let m = StoreManifest {
                dims,
                n_obs,
                segments: Vec::new(),
            };
            m.save(&dir)?;
            m
        };
        Ok(StoreWriter { dir, manifest })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    /// Open a segment writer for one slice run.
    pub fn open_segment(&self, slice: usize, method: &str, types: usize) -> Result<SegmentWriter> {
        SegmentWriter::create(&self.dir, slice, method, types)
    }

    /// Register a finished segment and persist the manifest. A segment
    /// with the same file name (same slice/method/types rerun) replaces
    /// its previous entry. Segments stay in completion order, which is
    /// what gives slice resolution its last-writer-wins semantics.
    pub fn add_segment(&mut self, meta: SegmentMeta) -> Result<()> {
        self.manifest.segments.retain(|s| s.file != meta.file);
        self.manifest.segments.push(meta);
        self.manifest.save(&self.dir)
    }
}

/// Read side: manifest + one open reader per segment. Opening validates
/// lengths, magics and the footer index — no payload rescan.
pub struct PdfStore {
    pub dir: PathBuf,
    pub manifest: StoreManifest,
    segments: Vec<SegmentReader>,
    /// slice → index into `segments`; a slice persisted twice (different
    /// method/types) resolves to the most recently completed segment
    /// (manifest entries are kept in completion order).
    by_slice: HashMap<usize, usize>,
}

impl PdfStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<PdfStore> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = StoreManifest::load(&dir)?;
        let mut segments = Vec::with_capacity(manifest.segments.len());
        let mut by_slice = HashMap::new();
        for (i, meta) in manifest.segments.iter().enumerate() {
            let reader = SegmentReader::open(&dir, meta)?;
            by_slice.insert(meta.slice, i);
            segments.push(reader);
        }
        Ok(PdfStore {
            dir,
            manifest,
            segments,
            by_slice,
        })
    }

    pub fn n_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn n_records(&self) -> u64 {
        self.manifest.segments.iter().map(|s| s.n_records).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.manifest.segments.iter().map(|s| s.bytes).sum()
    }

    pub fn segment(&self, idx: usize) -> &SegmentReader {
        &self.segments[idx]
    }

    /// Segment serving slice `z`, if persisted.
    pub fn segment_for_slice(&self, z: usize) -> Option<(usize, &SegmentReader)> {
        self.by_slice.get(&z).map(|&i| (i, &self.segments[i]))
    }

    /// Full-payload checksum verification of every segment (reads all
    /// bytes; open() itself stays index-only).
    pub fn verify(&self) -> Result<()> {
        for seg in &self.segments {
            seg.verify()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_codec_roundtrip_exact_width() {
        let rec = PdfRecord {
            point: PointId(123_456_789_012),
            dist: DistType::Weibull,
            error: 0.125,
            params: [1.5, -2.25, 0.0],
        };
        let mut buf = [0u8; REC_LEN];
        rec.encode(&mut buf);
        let back = PdfRecord::decode(&buf).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn record_decode_rejects_bad_type_and_short_buffer() {
        let mut buf = [0u8; REC_LEN];
        PdfRecord {
            point: PointId(1),
            dist: DistType::Normal,
            error: 0.0,
            params: [0.0; 3],
        }
        .encode(&mut buf);
        buf[8] = 42; // type id out of range
        assert!(PdfRecord::decode(&buf).is_err());
        assert!(PdfRecord::decode(&buf[..REC_LEN - 1]).is_err());
    }

    #[test]
    fn fnv64_is_stable_and_sensitive() {
        let a = fnv64(b"pdfstore");
        assert_eq!(a, fnv64(b"pdfstore"));
        assert_ne!(a, fnv64(b"pdfstorf"));
        let mut streaming = Fnv64::new();
        streaming.update(b"pdf");
        streaming.update(b"store");
        assert_eq!(streaming.finish(), a);
    }

    #[test]
    fn manifest_roundtrip_and_tamper_detection() {
        let dir = std::env::temp_dir().join(format!("pdfflow-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = StoreManifest {
            dims: CubeDims::new(16, 12, 8),
            n_obs: 100,
            segments: vec![SegmentMeta {
                file: "slice1_baseline_4.seg".into(),
                slice: 1,
                method: "baseline".into(),
                types: 4,
                n_windows: 3,
                n_records: 192,
                bytes: 5412,
                checksum: 0xdead_beef_cafe_f00d,
            }],
        };
        m.save(&dir).unwrap();
        let back = StoreManifest::load(&dir).unwrap();
        assert_eq!(back.dims, m.dims);
        assert_eq!(back.n_obs, 100);
        assert_eq!(back.segments, m.segments);
        // Tamper with one digit inside the body: checksum must catch it.
        let path = dir.join(MANIFEST_NAME);
        let text = std::fs::read_to_string(&path).unwrap();
        let tampered = text.replacen("\"slice\":1", "\"slice\":2", 1);
        assert_ne!(text, tampered);
        std::fs::write(&path, tampered).unwrap();
        assert!(StoreManifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
