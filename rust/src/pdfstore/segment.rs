//! Segment files: fixed-width record payload + footer window index.
//!
//! One segment holds one slice run's fitted PDFs in window order (window
//! order == point-id order inside a window, so a point lookup is pure
//! arithmetic once its window entry is known). The writer streams — it
//! never buffers more than one window — and maintains a running FNV-64
//! over everything written; `finish()` appends the footer index and the
//! checksummed trailer. The reader opens from the trailer alone (seek to
//! end, read index), which is what lets a store reopen cold with no
//! payload rescan.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::os::unix::fs::FileExt;
use std::path::Path;

use crate::coordinator::methods::FitOutcome;
use crate::cube::{PointId, Window};
use crate::pdfstore::{Fnv64, PdfRecord, FORMAT_VERSION, REC_LEN};
use crate::{PdfflowError, Result};

/// Segment header magic.
pub const SEG_MAGIC: &[u8; 4] = b"PDFS";
/// Trailer magic (end of file).
pub const TRAILER_MAGIC: &[u8; 4] = b"SFTR";
/// Header bytes: magic + version.
pub const HEADER_LEN: u64 = 8;
/// Footer bytes per window entry.
pub const ENTRY_LEN: u64 = 40;
/// Trailer bytes: footer_off + n_windows + checksum + magic.
pub const TRAILER_LEN: u64 = 28;

/// One window's byte range inside a segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WindowEntry {
    pub y0: u64,
    pub lines: u64,
    /// Absolute byte offset of the window's first record.
    pub offset: u64,
    pub n_records: u64,
    /// FNV-64 over the window's record payload, validated on every
    /// `read_window` — the granule that lets the query path catch bit
    /// rot at read time instead of waiting for a full `verify` pass.
    pub checksum: u64,
}

impl WindowEntry {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.y0.to_le_bytes());
        out.extend_from_slice(&self.lines.to_le_bytes());
        out.extend_from_slice(&self.offset.to_le_bytes());
        out.extend_from_slice(&self.n_records.to_le_bytes());
        out.extend_from_slice(&self.checksum.to_le_bytes());
    }

    fn decode(b: &[u8]) -> WindowEntry {
        let u64_at = |o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
        WindowEntry {
            y0: u64_at(0),
            lines: u64_at(8),
            offset: u64_at(16),
            n_records: u64_at(24),
            checksum: u64_at(32),
        }
    }
}

/// Catalog entry describing one finished segment. Every segment is
/// stamped with its full run identity `(method, types, run)` plus the
/// generation it was written in — the coordinates the generational
/// catalog resolves reads by.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentMeta {
    /// File name inside the store directory.
    pub file: String,
    pub slice: usize,
    pub method: String,
    /// Candidate-type count of the producing run.
    pub types: usize,
    /// Run id of the producing run (see [`crate::pdfstore::RunKey`]).
    pub run: String,
    /// Generation within the run: reruns of a slice append `gen + 1`
    /// instead of overwriting, compaction publishes a fresh generation.
    pub gen: usize,
    pub n_windows: usize,
    pub n_records: u64,
    /// Total file length in bytes (truncation guard).
    pub bytes: u64,
    /// FNV-64 over every byte before the trailer's checksum field.
    pub checksum: u64,
    /// Merged, sorted `[start, end)` line ranges this segment's windows
    /// cover. Persisted in the catalog so that after a segment is
    /// quarantined the store can prove whether the surviving
    /// generations still cover every line the run ever served — a
    /// coverage mismatch makes the slice a typed error instead of a
    /// silently shrunken answer.
    pub cover: Vec<(u64, u64)>,
}

/// Streaming writer for one segment. Records stream into a `.tmp` file
/// that is renamed over the final name only in `finish()`, so a crashed
/// or abandoned run never clobbers a manifest-registered segment — the
/// store on disk stays openable throughout a rerun.
pub struct SegmentWriter {
    f: BufWriter<File>,
    tmp_path: std::path::PathBuf,
    final_path: std::path::PathBuf,
    file_name: String,
    slice: usize,
    method: String,
    types: usize,
    run: String,
    gen: usize,
    entries: Vec<WindowEntry>,
    hash: Fnv64,
    /// Bytes written so far (everything the checksum covers).
    offset: u64,
    n_records: u64,
}

impl SegmentWriter {
    /// Open a segment for `(slice, method, types, run, gen)`. The file
    /// name carries all five coordinates, so two runs — or two
    /// generations of one run — can never collide on disk.
    pub fn create(
        dir: &Path,
        slice: usize,
        method: &str,
        types: usize,
        run: &str,
        gen: usize,
    ) -> Result<SegmentWriter> {
        let file_name = format!("slice{slice}_{method}_{types}_{run}_g{gen}.seg");
        let final_path = dir.join(&file_name);
        let tmp_path = dir.join(format!("{file_name}.tmp"));
        let mut w = SegmentWriter {
            f: BufWriter::new(File::create(&tmp_path)?),
            tmp_path,
            final_path,
            file_name,
            slice,
            method: method.to_string(),
            types,
            run: run.to_string(),
            gen,
            entries: Vec::new(),
            hash: Fnv64::new(),
            offset: 0,
            n_records: 0,
        };
        w.write(SEG_MAGIC)?;
        w.write(&FORMAT_VERSION.to_le_bytes())?;
        Ok(w)
    }

    /// Hash-then-write. The running checksum always covers the
    /// *original* bytes; when a `segment.write` corruption fault is
    /// armed, only the copy that reaches the disk is mangled — so
    /// injected write corruption stays detectable by the same checks
    /// that catch real bit rot, instead of being checksummed into
    /// truth.
    fn write(&mut self, bytes: &[u8]) -> Result<()> {
        self.hash.update(bytes);
        self.offset += bytes.len() as u64;
        if crate::fault::active() {
            let mut copy = bytes.to_vec();
            crate::fault::mangle("segment.write", &mut copy);
            self.f.write_all(&copy)?;
        } else {
            self.f.write_all(bytes)?;
        }
        Ok(())
    }

    /// Append one window's outcomes (the pipeline's persist phase calls
    /// this once per window, in slice order). Returns the bytes written.
    pub fn append_window(
        &mut self,
        window: &Window,
        ids: &[PointId],
        outcomes: &[FitOutcome],
    ) -> Result<u64> {
        let _span = crate::span!("segment.write", "y0 {} x{}", window.y0, ids.len());
        if window.z != self.slice {
            return Err(PdfflowError::InvalidArg(format!(
                "segment holds slice {}, got window of slice {}",
                self.slice, window.z
            )));
        }
        if ids.len() != outcomes.len() {
            return Err(PdfflowError::InvalidArg(format!(
                "{} ids vs {} outcomes",
                ids.len(),
                outcomes.len()
            )));
        }
        self.check_line_order(window.y0 as u64)?;
        crate::fault::check("segment.write")?;
        let start = self.offset;
        let mut buf = [0u8; REC_LEN];
        let mut win_hash = Fnv64::new();
        for (id, o) in ids.iter().zip(outcomes) {
            PdfRecord {
                point: *id,
                dist: o.dist,
                error: o.error,
                params: o.params,
            }
            .encode(&mut buf);
            win_hash.update(&buf);
            self.write(&buf)?;
        }
        self.entries.push(WindowEntry {
            y0: window.y0 as u64,
            lines: window.lines as u64,
            offset: start,
            n_records: ids.len() as u64,
            checksum: win_hash.finish(),
        });
        self.n_records += ids.len() as u64;
        Ok(self.offset - start)
    }

    /// Append one window of already-decoded records (compaction's
    /// rewrite path). Bit-exact: `PdfRecord` encode∘decode is the
    /// identity on the 28-byte wire form, so a compacted segment holds
    /// byte-identical record payloads.
    pub fn append_records(&mut self, y0: u64, lines: u64, records: &[PdfRecord]) -> Result<u64> {
        self.check_line_order(y0)?;
        crate::fault::check("segment.write")?;
        let start = self.offset;
        let mut buf = [0u8; REC_LEN];
        let mut win_hash = Fnv64::new();
        for rec in records {
            rec.encode(&mut buf);
            win_hash.update(&buf);
            self.write(&buf)?;
        }
        self.entries.push(WindowEntry {
            y0,
            lines,
            offset: start,
            n_records: records.len() as u64,
            checksum: win_hash.finish(),
        });
        self.n_records += records.len() as u64;
        Ok(self.offset - start)
    }

    fn check_line_order(&self, y0: u64) -> Result<()> {
        if let Some(last) = self.entries.last() {
            if y0 < last.y0 + last.lines {
                return Err(PdfflowError::InvalidArg(format!(
                    "windows must be appended in line order: y0 {} after y0 {} (+{} lines)",
                    y0, last.y0, last.lines
                )));
            }
        }
        Ok(())
    }

    /// Write the footer index + checksummed trailer and close the file.
    pub fn finish(mut self) -> Result<SegmentMeta> {
        let _span = crate::span!("segment.finish", "{}", self.file_name);
        let footer_off = self.offset;
        let mut footer = Vec::with_capacity(self.entries.len() * ENTRY_LEN as usize + 16);
        for e in &self.entries {
            e.encode(&mut footer);
        }
        footer.extend_from_slice(&footer_off.to_le_bytes());
        footer.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        self.write(&footer)?;
        // Checksum covers everything written so far; the checksum field
        // and trailer magic themselves are excluded.
        let checksum = self.hash.finish();
        self.f.write_all(&checksum.to_le_bytes())?;
        self.f.write_all(TRAILER_MAGIC)?;
        self.f.flush()?;
        drop(self.f);
        crate::fault::check("segment.finish")?;
        std::fs::rename(&self.tmp_path, &self.final_path)?;
        // Merge adjacent windows into the covered-line ranges; entries
        // are in line order, so one forward pass suffices.
        let mut cover: Vec<(u64, u64)> = Vec::new();
        for e in &self.entries {
            let end = e.y0 + e.lines;
            match cover.last_mut() {
                Some(last) if last.1 == e.y0 => last.1 = end,
                _ => cover.push((e.y0, end)),
            }
        }
        Ok(SegmentMeta {
            file: self.file_name,
            slice: self.slice,
            method: self.method,
            types: self.types,
            run: self.run,
            gen: self.gen,
            n_windows: self.entries.len(),
            n_records: self.n_records,
            bytes: self.offset + 12,
            checksum,
            cover,
        })
    }
}

/// Raw read-only file mapping (unix + `mmap` feature). Uses the mmap /
/// munmap syscalls straight through the C symbols std already links —
/// no crate — so the build stays dependency-free on the offline image.
#[cfg(all(feature = "mmap", unix))]
mod mapped {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    // MAP_SHARED (not PRIVATE): post-open writes to the file stay
    // visible through the mapping, so on-disk corruption that lands
    // after open is still caught by the per-window checksums instead of
    // being masked by copy-on-write snapshots.
    const MAP_SHARED: i32 = 1;

    /// Whole-file read-only mapping, unmapped on drop.
    pub struct SegMap {
        ptr: *const u8,
        len: usize,
    }

    // The mapping is never written through and lives exactly as long as
    // the owning reader; concurrent shared reads are safe.
    unsafe impl Send for SegMap {}
    unsafe impl Sync for SegMap {}

    impl SegMap {
        /// `None` when the file is empty, too large for the address
        /// space, or the syscall fails — the reader then serves every
        /// read through the buffered path, exactly like a non-mmap
        /// build.
        pub fn new(file: &File, len: u64) -> Option<SegMap> {
            let len = usize::try_from(len).ok()?;
            if len == 0 {
                return None;
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_SHARED, file.as_raw_fd(), 0)
            };
            if ptr.is_null() || ptr as usize == usize::MAX {
                return None;
            }
            Some(SegMap { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    impl Drop for SegMap {
        fn drop(&mut self) {
            unsafe { munmap(self.ptr as *mut u8, self.len) };
        }
    }
}

/// Open segment: shared file handle (positioned reads, thread-safe) plus
/// the decoded window index.
pub struct SegmentReader {
    file: File,
    pub meta: SegmentMeta,
    pub entries: Vec<WindowEntry>,
    /// Whole-file read-only mapping: lets the query engine borrow warm
    /// window payloads instead of round-tripping them through the block
    /// cache. `None` when mapping failed; reads then fall back to
    /// `read_window`.
    #[cfg(all(feature = "mmap", unix))]
    map: Option<mapped::SegMap>,
    /// One first-touch checksum flag per window. Flags are per-reader,
    /// and a quarantined reader is never reused, so "validated once per
    /// reader" is "validated once per resolve epoch" from the engine's
    /// point of view.
    #[cfg(all(feature = "mmap", unix))]
    validated: Vec<std::sync::atomic::AtomicBool>,
}

impl SegmentReader {
    /// Open and validate against the manifest entry: file length, header
    /// and trailer magics, stored checksum, and footer-index geometry.
    /// Reads header + footer only — never the record payload.
    pub fn open(dir: &Path, meta: &SegmentMeta) -> Result<SegmentReader> {
        let path = dir.join(&meta.file);
        let file = File::open(&path)?;
        let len = file.metadata()?.len();
        let bad = |what: String| PdfflowError::Format(format!("{}: {what}", path.display()));
        if len != meta.bytes {
            return Err(bad(format!(
                "length {len} != manifest {} (truncated or appended?)",
                meta.bytes
            )));
        }
        if len < HEADER_LEN + TRAILER_LEN {
            return Err(bad(format!("too short ({len} bytes)")));
        }
        let mut hdr = [0u8; 8];
        file.read_exact_at(&mut hdr, 0)?;
        if &hdr[0..4] != SEG_MAGIC {
            return Err(bad("bad header magic".into()));
        }
        let version = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(bad(format!("unsupported segment version {version}")));
        }
        let mut trailer = [0u8; TRAILER_LEN as usize];
        file.read_exact_at(&mut trailer, len - TRAILER_LEN)?;
        if &trailer[24..28] != TRAILER_MAGIC {
            return Err(bad("bad trailer magic".into()));
        }
        let footer_off = u64::from_le_bytes(trailer[0..8].try_into().unwrap());
        let n_windows = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
        let checksum = u64::from_le_bytes(trailer[16..24].try_into().unwrap());
        if checksum != meta.checksum {
            return Err(bad(format!(
                "trailer checksum {checksum:016x} != manifest {:016x}",
                meta.checksum
            )));
        }
        // All trailer/footer fields are untrusted: checked arithmetic so
        // corrupt values surface as Format errors, never overflow.
        let expect_len = n_windows
            .checked_mul(ENTRY_LEN)
            .and_then(|v| v.checked_add(footer_off))
            .and_then(|v| v.checked_add(TRAILER_LEN));
        if footer_off < HEADER_LEN || expect_len != Some(len) {
            return Err(bad(format!(
                "inconsistent footer: offset {footer_off}, {n_windows} windows, length {len}"
            )));
        }
        let mut fb = vec![0u8; (n_windows * ENTRY_LEN) as usize];
        file.read_exact_at(&mut fb, footer_off)?;
        let mut entries = Vec::with_capacity(n_windows as usize);
        let mut expect_next_y0 = 0u64;
        for chunk in fb.chunks_exact(ENTRY_LEN as usize) {
            let e = WindowEntry::decode(chunk);
            let end = e
                .n_records
                .checked_mul(REC_LEN as u64)
                .and_then(|v| v.checked_add(e.offset));
            if e.offset < HEADER_LEN
                || !matches!(end, Some(end) if end <= footer_off)
                || e.y0 < expect_next_y0
            {
                return Err(bad(format!(
                    "corrupt window entry (y0 {}, offset {}, {} records)",
                    e.y0, e.offset, e.n_records
                )));
            }
            expect_next_y0 = e.y0.saturating_add(e.lines);
            entries.push(e);
        }
        Ok(SegmentReader {
            #[cfg(all(feature = "mmap", unix))]
            map: mapped::SegMap::new(&file, len),
            #[cfg(all(feature = "mmap", unix))]
            validated: entries.iter().map(|_| std::sync::atomic::AtomicBool::new(false)).collect(),
            file,
            meta: meta.clone(),
            entries,
        })
    }

    /// Index of the window covering line `y`, if any.
    pub fn find_window(&self, y: usize) -> Option<usize> {
        let y = y as u64;
        // Entries are sorted by y0 and non-overlapping.
        let idx = self.entries.partition_point(|e| e.y0 <= y);
        if idx == 0 {
            return None;
        }
        let e = &self.entries[idx - 1];
        (y < e.y0 + e.lines).then_some(idx - 1)
    }

    /// Read, checksum-validate and decode one window's records (one
    /// positioned read). Transient read errors are retried per
    /// [`crate::fault::retry`]; a per-window checksum mismatch is a
    /// permanent `Format` error the query engine turns into a
    /// quarantine.
    pub fn read_window(&self, idx: usize) -> Result<Vec<PdfRecord>> {
        let _span = crate::span!("segment.read", "{} win {idx}", self.meta.file);
        let e = &self.entries[idx];
        let mut buf = vec![0u8; (e.n_records as usize) * REC_LEN];
        crate::fault::retry("segment.read", || {
            crate::fault::check("segment.read")?;
            self.file.read_exact_at(&mut buf, e.offset)?;
            Ok(())
        })?;
        crate::fault::mangle("segment.read", &mut buf);
        let got = crate::pdfstore::fnv64(&buf);
        if got != e.checksum {
            return Err(PdfflowError::Format(format!(
                "{} window {idx}: payload checksum {got:016x} != index {:016x} (corrupt segment)",
                self.meta.file, e.checksum
            )));
        }
        decode_records(&buf)
    }

    /// Full-payload FNV-64 verification against the manifest checksum
    /// (streams the whole file; the expensive counterpart of `open`).
    pub fn verify(&self) -> Result<()> {
        let len = self.meta.bytes;
        let covered = len - 12; // checksum field + trailer magic excluded
        let mut hash = Fnv64::new();
        let mut buf = vec![0u8; 1 << 16];
        let mut off = 0u64;
        while off < covered {
            let take = buf.len().min((covered - off) as usize);
            self.file.read_exact_at(&mut buf[..take], off)?;
            hash.update(&buf[..take]);
            off += take as u64;
        }
        let got = hash.finish();
        if got != self.meta.checksum {
            return Err(PdfflowError::Format(format!(
                "{}: payload checksum {got:016x} != manifest {:016x} (corrupt segment)",
                self.meta.file, self.meta.checksum
            )));
        }
        Ok(())
    }
}

/// Zero-copy read path. Every method returns `None` when no mapping is
/// available (syscall failed, non-unix, feature off at the call site) —
/// callers fall back to the buffered [`SegmentReader::read_window`]
/// path, which keeps semantics identical across platforms.
#[cfg(all(feature = "mmap", unix))]
impl SegmentReader {
    /// Whether this reader carries a usable file mapping.
    pub fn has_map(&self) -> bool {
        self.map.is_some()
    }

    /// Borrow one window's raw payload out of the mapping.
    fn window_payload(&self, idx: usize) -> Option<&[u8]> {
        let e = &self.entries[idx];
        let start = e.offset as usize;
        let end = start + e.n_records as usize * REC_LEN;
        self.map.as_ref()?.bytes().get(start..end)
    }

    /// Checksum-validate a mapped window payload on first touch; later
    /// touches of the same window skip straight to decoding.
    fn validate_window(&self, idx: usize, payload: &[u8]) -> Result<()> {
        use std::sync::atomic::Ordering;
        if self.validated[idx].load(Ordering::Acquire) {
            return Ok(());
        }
        let e = &self.entries[idx];
        let got = crate::pdfstore::fnv64(payload);
        if got != e.checksum {
            return Err(PdfflowError::Format(format!(
                "{} window {idx}: payload checksum {got:016x} != index {:016x} (corrupt segment)",
                self.meta.file, e.checksum
            )));
        }
        self.validated[idx].store(true, Ordering::Release);
        Ok(())
    }

    /// Decode one whole window straight out of the mapping — no block
    /// cache, no read syscall. Under armed fault injection the payload
    /// is copied and mangled exactly like the buffered path and the
    /// validated flag is never set, so injected corruption stays as
    /// detectable here as there.
    pub fn mmap_window(&self, idx: usize) -> Option<Result<Vec<PdfRecord>>> {
        let payload = self.window_payload(idx)?;
        if crate::fault::active() {
            return Some(self.mmap_window_faulted(idx, payload));
        }
        if let Err(e) = self.validate_window(idx, payload) {
            return Some(Err(e));
        }
        Some(decode_records(payload))
    }

    fn mmap_window_faulted(&self, idx: usize, payload: &[u8]) -> Result<Vec<PdfRecord>> {
        let mut copy = payload.to_vec();
        crate::fault::retry("segment.read", || crate::fault::check("segment.read"))?;
        crate::fault::mangle("segment.read", &mut copy);
        let e = &self.entries[idx];
        let got = crate::pdfstore::fnv64(&copy);
        if got != e.checksum {
            return Err(PdfflowError::Format(format!(
                "{} window {idx}: payload checksum {got:016x} != index {:016x} (corrupt segment)",
                self.meta.file, e.checksum
            )));
        }
        decode_records(&copy)
    }

    /// Decode a single record out of a mapped window — the point-query
    /// fast path: first touch checksums the whole window, every later
    /// hit is one 28-byte decode with zero copies of the payload. Falls
    /// back to the buffered path (`None`) under armed fault injection so
    /// injected read faults keep their deterministic schedule.
    pub fn mmap_record(&self, idx: usize, rec: usize) -> Option<Result<PdfRecord>> {
        if crate::fault::active() {
            return None;
        }
        let payload = self.window_payload(idx)?;
        if let Err(e) = self.validate_window(idx, payload) {
            return Some(Err(e));
        }
        let start = rec * REC_LEN;
        let chunk = payload.get(start..start + REC_LEN)?;
        Some(PdfRecord::decode(chunk))
    }
}

fn decode_records(payload: &[u8]) -> Result<Vec<PdfRecord>> {
    let mut out = Vec::with_capacity(payload.len() / REC_LEN);
    for chunk in payload.chunks_exact(REC_LEN) {
        out.push(PdfRecord::decode(chunk)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::DistType;
    use std::path::PathBuf;

    fn outcomes(n: usize, seed: u32) -> Vec<FitOutcome> {
        (0..n)
            .map(|i| FitOutcome {
                dist: DistType::from_id((i + seed as usize) % 10).unwrap(),
                error: 0.01 * (i as f32 + seed as f32),
                params: [i as f32, -(i as f32), 0.5],
            })
            .collect()
    }

    fn ids(start: u64, n: usize) -> Vec<PointId> {
        (0..n as u64).map(|i| PointId(start + i)).collect()
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pdfflow-seg-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_then_read_windows_back() {
        let dir = tmp("rw");
        let mut w = SegmentWriter::create(&dir, 3, "baseline", 4, "default", 0).unwrap();
        let w0 = Window { z: 3, y0: 0, lines: 2 };
        let w1 = Window { z: 3, y0: 2, lines: 1 };
        let o0 = outcomes(8, 0);
        let o1 = outcomes(4, 5);
        w.append_window(&w0, &ids(100, 8), &o0).unwrap();
        w.append_window(&w1, &ids(200, 4), &o1).unwrap();
        let meta = w.finish().unwrap();
        assert_eq!(meta.n_windows, 2);
        assert_eq!(meta.n_records, 12);
        assert_eq!(meta.file, "slice3_baseline_4_default_g0.seg");
        assert_eq!((meta.run.as_str(), meta.gen), ("default", 0));
        assert_eq!(meta.cover, vec![(0, 3)], "adjacent windows merge into one range");
        assert_eq!(
            meta.bytes,
            HEADER_LEN + 12 * REC_LEN as u64 + 2 * ENTRY_LEN + TRAILER_LEN
        );

        let r = SegmentReader::open(&dir, &meta).unwrap();
        r.verify().unwrap();
        assert_eq!(r.entries.len(), 2);
        assert_eq!(r.find_window(0), Some(0));
        assert_eq!(r.find_window(1), Some(0));
        assert_eq!(r.find_window(2), Some(1));
        assert_eq!(r.find_window(3), None);
        let back = r.read_window(1).unwrap();
        assert_eq!(back.len(), 4);
        assert_eq!(back[0].point, PointId(200));
        assert_eq!(back[3].error, o1[3].error);
        assert_eq!(back[2].params, o1[2].params);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn append_records_is_bit_identical_to_append_window() {
        // Compaction's rewrite path must reproduce the exact bytes the
        // outcome path wrote.
        let dir = tmp("recs");
        let mut w = SegmentWriter::create(&dir, 5, "grouping", 4, "a", 0).unwrap();
        let win = Window { z: 5, y0: 0, lines: 2 };
        w.append_window(&win, &ids(10, 6), &outcomes(6, 3)).unwrap();
        let meta = w.finish().unwrap();
        let original = std::fs::read(dir.join(&meta.file)).unwrap();
        let r = SegmentReader::open(&dir, &meta).unwrap();
        let records = r.read_window(0).unwrap();

        let mut w2 = SegmentWriter::create(&dir, 5, "grouping", 4, "a", 1).unwrap();
        w2.append_records(0, 2, &records).unwrap();
        let meta2 = w2.finish().unwrap();
        let rewritten = std::fs::read(dir.join(&meta2.file)).unwrap();
        assert_eq!(original, rewritten, "rewrite changed segment bytes");
        assert_eq!(meta.checksum, meta2.checksum);
        // Out-of-order record windows are rejected like outcome windows.
        let mut w3 = SegmentWriter::create(&dir, 5, "grouping", 4, "a", 2).unwrap();
        w3.append_records(4, 2, &records).unwrap();
        assert!(w3.append_records(3, 1, &records).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_out_of_order_windows_and_wrong_slice() {
        let dir = tmp("order");
        let mut w = SegmentWriter::create(&dir, 1, "baseline", 4, "default", 0).unwrap();
        w.append_window(&Window { z: 1, y0: 2, lines: 2 }, &ids(0, 4), &outcomes(4, 0))
            .unwrap();
        assert!(w
            .append_window(&Window { z: 1, y0: 1, lines: 1 }, &ids(0, 2), &outcomes(2, 0))
            .is_err());
        assert!(w
            .append_window(&Window { z: 2, y0: 4, lines: 1 }, &ids(0, 2), &outcomes(2, 0))
            .is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncation_is_rejected_at_open() {
        let dir = tmp("trunc");
        let mut w = SegmentWriter::create(&dir, 0, "baseline", 4, "default", 0).unwrap();
        w.append_window(&Window { z: 0, y0: 0, lines: 1 }, &ids(0, 6), &outcomes(6, 1))
            .unwrap();
        let meta = w.finish().unwrap();
        let path = dir.join(&meta.file);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(meta.bytes - 10).unwrap();
        drop(f);
        assert!(SegmentReader::open(&dir, &meta).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn payload_corruption_is_caught_by_verify() {
        let dir = tmp("corrupt");
        let mut w = SegmentWriter::create(&dir, 0, "baseline", 4, "default", 0).unwrap();
        w.append_window(&Window { z: 0, y0: 0, lines: 1 }, &ids(0, 6), &outcomes(6, 2))
            .unwrap();
        let meta = w.finish().unwrap();
        let path = dir.join(&meta.file);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0xFF; // flip a payload byte, length unchanged
        std::fs::write(&path, &bytes).unwrap();
        let r = SegmentReader::open(&dir, &meta).unwrap(); // index still sane
        assert!(r.verify().is_err());
        // The per-window checksum catches it at read time too — this is
        // what the query engine's quarantine path keys off.
        assert!(matches!(r.read_window(0), Err(PdfflowError::Format(_))));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gapped_windows_produce_split_cover() {
        let dir = tmp("cover");
        let mut w = SegmentWriter::create(&dir, 2, "baseline", 4, "default", 0).unwrap();
        w.append_window(&Window { z: 2, y0: 0, lines: 2 }, &ids(0, 4), &outcomes(4, 0))
            .unwrap();
        w.append_window(&Window { z: 2, y0: 5, lines: 1 }, &ids(9, 2), &outcomes(2, 1))
            .unwrap();
        let meta = w.finish().unwrap();
        assert_eq!(meta.cover, vec![(0, 2), (5, 6)]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(all(feature = "mmap", unix))]
    #[test]
    fn mmap_path_matches_buffered_path_and_catches_corruption() {
        let dir = tmp("mmap");
        let mut w = SegmentWriter::create(&dir, 0, "baseline", 4, "default", 0).unwrap();
        w.append_window(&Window { z: 0, y0: 0, lines: 2 }, &ids(0, 8), &outcomes(8, 4))
            .unwrap();
        w.append_window(&Window { z: 0, y0: 2, lines: 1 }, &ids(8, 4), &outcomes(4, 7))
            .unwrap();
        let meta = w.finish().unwrap();
        let r = SegmentReader::open(&dir, &meta).unwrap();
        assert!(r.has_map(), "loopback tmpfs should always map");
        for idx in 0..2 {
            let buffered = r.read_window(idx).unwrap();
            let mapped = r.mmap_window(idx).unwrap().unwrap();
            assert_eq!(buffered, mapped, "window {idx} differs across read paths");
            for (i, rec) in buffered.iter().enumerate() {
                let one = r.mmap_record(idx, i).unwrap().unwrap();
                assert_eq!(*rec, one);
            }
        }
        // Corruption flipped in after open is visible through the shared
        // mapping and caught by the first-touch checksum.
        let mut w2 = SegmentWriter::create(&dir, 1, "baseline", 4, "default", 0).unwrap();
        w2.append_window(&Window { z: 1, y0: 0, lines: 1 }, &ids(0, 6), &outcomes(6, 2))
            .unwrap();
        let meta2 = w2.finish().unwrap();
        let path = dir.join(&meta2.file);
        let r2 = SegmentReader::open(&dir, &meta2).unwrap();
        // In-place flip (no truncate: the inode is mapped).
        let bytes = std::fs::read(&path).unwrap();
        let off = HEADER_LEN + 3;
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.write_all_at(&[bytes[off as usize] ^ 0xFF], off).unwrap();
        drop(f);
        assert!(matches!(r2.mmap_window(0), Some(Err(PdfflowError::Format(_)))));
        assert!(matches!(r2.mmap_record(0, 0), Some(Err(PdfflowError::Format(_)))));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
