//! Compaction: collapse a run's generations into one dense layout.
//!
//! A long-lived run accumulates generations — every rerun of a slice
//! appends a new segment, and readers resolve window-by-window to the
//! newest one, leaving shadowed windows as dead bytes on disk and extra
//! open file handles per query. `compact_run` rewrites the run's
//! *resolved view* (exactly what queries can see, nothing else) into
//! one fresh segment per slice — windows sorted by `y0`, no shadowed
//! data, rebuilt footer index and trailer checksum — publishes it as a
//! new generation with one atomic catalog swap, and only then unlinks
//! the superseded files.
//!
//! Two properties fall out of that ordering:
//!
//! * **Bit-identical reads.** The rewrite streams decoded records
//!   through the same 28-byte codec (encode∘decode is the identity), in
//!   the same resolved window order a query would visit, so every
//!   point / region / analytic query answers identically before and
//!   after — pinned by `tests/store_generations.rs`.
//! * **Crash safety.** Until the catalog swap, new files are unlinked
//!   `.tmp`s or unreferenced `.seg`s that no open path ever touches; a
//!   crash at any point cold-opens to the previous generation with
//!   `verify()` clean. After the swap, old files are garbage whose
//!   deletion is best-effort.

use std::path::Path;

use crate::pdfstore::{
    Catalog, PdfStore, RunKey, RunSelector, SegmentMeta, SegmentWriter,
};
use crate::Result;

/// What one compaction did (CLI `pdfflow store compact` prints this).
#[derive(Clone, Debug)]
pub struct CompactReport {
    pub run: RunKey,
    /// Generation the compacted segments were published as. When the
    /// run was already dense this is the existing generation and
    /// nothing was rewritten.
    pub gen: usize,
    /// True when the run was already one dense generation (no-op).
    pub already_compact: bool,
    pub slices: usize,
    pub segments_before: usize,
    pub segments_after: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
    /// Records reachable through the resolved view (unchanged by
    /// compaction, by construction).
    pub records: u64,
    /// Superseded segment files unlinked after the catalog swap.
    pub retired_files: usize,
}

/// Compact one run of the store at `dir` (see module docs). `selector`
/// picks the run the way `pdfflow query --run` does: `None` = latest.
pub fn compact_run(dir: impl AsRef<Path>, selector: Option<&str>) -> Result<CompactReport> {
    let dir = dir.as_ref();
    let store = PdfStore::open_run(dir, RunSelector::from_opt(selector))?;
    let key = store.run_key().clone();
    let slices = store.slices();
    let segments_before = store.n_segments();
    let bytes_before = store.total_bytes();
    let records = store.n_records();

    // Already dense? One segment per slice and nothing shadowed means a
    // rewrite would reproduce the same files under a new name — skip.
    // A quarantined segment is never dense: the rewrite is exactly how
    // its resolved stand-ins become durable.
    let dense = store.n_quarantined() == 0
        && store.run().segments.len() == slices.len()
        && slices.iter().all(|&z| {
            let parts = store.resolved_parts(z).map(|p| p.len()).unwrap_or(0);
            let seg_windows: usize = store
                .run()
                .segments
                .iter()
                .enumerate()
                .filter(|(_, s)| s.slice == z)
                .map(|(i, _)| store.reader(i).map(|r| r.entries.len()).unwrap_or(0))
                .sum();
            parts == seg_windows
        });
    if dense {
        return Ok(CompactReport {
            gen: store.run().max_gen().unwrap_or(0),
            already_compact: true,
            slices: slices.len(),
            segments_before,
            segments_after: segments_before,
            bytes_before,
            bytes_after: bytes_before,
            records,
            retired_files: 0,
            run: key,
        });
    }

    let new_gen = store.run().max_gen().map(|g| g + 1).unwrap_or(0);
    let old_files: Vec<String> = store.run().segments.iter().map(|s| s.file.clone()).collect();

    let new_metas = rewrite_resolved(dir, &store, new_gen)?;
    let bytes_after = new_metas.iter().map(|m| m.bytes).sum();
    let segments_after = new_metas.len();

    drop(store);
    let retired = publish_run(dir, &key, new_metas, &old_files)?;
    Ok(CompactReport {
        run: key,
        gen: new_gen,
        already_compact: false,
        slices: slices.len(),
        segments_before,
        segments_after,
        bytes_before,
        bytes_after,
        records,
        retired_files: retired,
    })
}

/// Rewrite `store`'s resolved view into one dense segment per slice at
/// generation `new_gen`. Files are complete (tmp + rename inside
/// `finish`) before anything points at them. Shared by compaction and
/// by scrub's `--repair`, which is what lets a repair reuse the
/// bit-identical rewrite path.
pub(crate) fn rewrite_resolved(
    dir: &Path,
    store: &PdfStore,
    new_gen: usize,
) -> Result<Vec<SegmentMeta>> {
    let key = store.run_key();
    let slices = store.slices();
    let mut new_metas: Vec<SegmentMeta> = Vec::with_capacity(slices.len());
    for &z in &slices {
        let parts = store.slice_parts(z)?.expect("slice listed but unresolved");
        let mut w = SegmentWriter::create(dir, z, &key.method, key.types, &key.run_id, new_gen)?;
        for part in parts.iter() {
            let records = store.reader(part.seg)?.read_window(part.win)?;
            w.append_records(part.entry.y0, part.entry.lines, &records)?;
        }
        new_metas.push(w.finish()?);
    }
    Ok(new_metas)
}

/// Publish rewritten segments: reload the catalog (the caller's open
/// holds a snapshot), swap the run's segment list, save atomically —
/// the single point where readers move to the new generation — then
/// retire the superseded files (garbage now, deletion best-effort; a
/// crash here just leaves unreferenced files). Returns the retired
/// count.
pub(crate) fn publish_run(
    dir: &Path,
    key: &RunKey,
    new_metas: Vec<SegmentMeta>,
    old_files: &[String],
) -> Result<usize> {
    crate::fault::check("compact.publish")?;
    let mut catalog = Catalog::load(dir)?;
    catalog.replace_run_segments(key, new_metas)?;
    catalog.save(dir)?;
    let mut retired = 0usize;
    for f in old_files {
        if std::fs::remove_file(dir.join(f)).is_ok() {
            retired += 1;
        }
    }
    Ok(retired)
}
