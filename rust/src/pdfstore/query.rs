//! The store's read path: point / region / analytical queries behind a
//! sharded LRU block cache.
//!
//! The cache unit is one decoded window block (the segment's natural
//! read granularity), sharded by key hash so concurrent query threads
//! rarely contend on the same mutex — query throughput under threads is
//! a first-class benchmark (`cargo bench --bench queries`). Hit / miss /
//! eviction meters are atomic and cheap enough to stay always-on, the
//! same observability contract as [`crate::storage::WindowCache`] —
//! both fronts share the generic [`crate::util::lru::ShardedStampLru`]
//! core.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::cube::{CellGrid, CubeDims, PointId};
use crate::executor::Executor;
use crate::pdfstore::{PdfRecord, PdfStore, RunSelector, SlicePart, REC_LEN};
use crate::runtime::hostpool;
use crate::spatial::{
    dist2, dominant_type, BoxQuery, CellSummary, GridIndex, KnnQuery, RadiusQuery, RunDiff,
    SpatialAggregate,
};
use crate::stats::{self, density, PENALTY_ERROR};
use crate::util::lru::ShardedStampLru;
use crate::{PdfflowError, Result};

/// Block cache key: (segment index, window index).
type BlockKey = (u32, u32);

/// Aggregated cache observability counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheMeters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub bytes: u64,
    pub entries: usize,
}

/// Sharded LRU over decoded window blocks with a global byte budget
/// split evenly across shards (a front over the generic
/// [`ShardedStampLru`] core, weighed by encoded record bytes).
pub struct ShardedLru {
    lru: ShardedStampLru<BlockKey, Arc<Vec<PdfRecord>>>,
}

impl ShardedLru {
    pub fn new(capacity_bytes: u64, n_shards: usize) -> ShardedLru {
        ShardedLru {
            // Mirrored in the process registry as `cache.qblock.*`
            // (summed across engines; `meters()` stays instance-exact).
            lru: ShardedStampLru::with_label(
                capacity_bytes,
                n_shards,
                |b: &Arc<Vec<PdfRecord>>| (b.len() * REC_LEN) as u64,
                "qblock",
            ),
        }
    }

    pub fn get(&self, key: &BlockKey) -> Option<Arc<Vec<PdfRecord>>> {
        self.lru.get(key)
    }

    pub fn put(&self, key: BlockKey, block: Arc<Vec<PdfRecord>>) {
        self.lru.put(key, block)
    }

    pub fn meters(&self) -> CacheMeters {
        let s = self.lru.stats();
        CacheMeters {
            hits: s.hits,
            misses: s.misses,
            evictions: s.evictions,
            bytes: s.bytes,
            entries: s.entries,
        }
    }

    pub fn clear(&self) {
        self.lru.clear()
    }
}

/// Inclusive rectangular region of one slice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionQuery {
    pub z: usize,
    pub x0: usize,
    pub x1: usize,
    pub y0: usize,
    pub y1: usize,
}

impl RegionQuery {
    /// Whole slice `z` of a cube.
    pub fn slice(dims: &CubeDims, z: usize) -> RegionQuery {
        RegionQuery {
            z,
            x0: 0,
            x1: dims.nx.saturating_sub(1),
            y0: 0,
            y1: dims.ny.saturating_sub(1),
        }
    }

    pub fn n_points(&self) -> usize {
        if self.x1 < self.x0 || self.y1 < self.y0 {
            return 0;
        }
        (self.x1 - self.x0 + 1) * (self.y1 - self.y0 + 1)
    }
}

/// Error-histogram bins in a [`RegionSummary`] (over [0, PENALTY_ERROR]).
pub const ERROR_HIST_BINS: usize = 8;

/// Aggregate answer for an analytical region query.
#[derive(Clone, Debug, PartialEq)]
pub struct RegionSummary {
    pub n_points: usize,
    pub avg_error: f64,
    pub max_error: f64,
    /// Count per `DistType` id (the paper's type-percentage vector).
    pub type_counts: [u64; 10],
    /// Equal-width histogram of Eq.5 errors over [0, PENALTY_ERROR].
    pub error_hist: [u64; ERROR_HIST_BINS],
}

impl RegionSummary {
    fn empty() -> RegionSummary {
        RegionSummary {
            n_points: 0,
            avg_error: 0.0,
            max_error: 0.0,
            type_counts: [0; 10],
            error_hist: [0; ERROR_HIST_BINS],
        }
    }
}

/// Which physical path serves window payloads.
///
/// Both paths return bit-identical records — `Mmap` is a latency /
/// memory-traffic optimization, never a semantic one — and `Mmap`
/// silently degrades to `Cached` per read wherever no file mapping is
/// available (non-unix build, `--no-default-features`, or a failed
/// mmap syscall). The `store.read_path.{mmap,cached}` counter pair
/// records which path actually served each read.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPath {
    /// Decoded window blocks round-trip through the sharded LRU block
    /// cache (the original path; default for batch/query workloads).
    #[default]
    Cached,
    /// Borrow window payloads from the mmap'd segment file and decode
    /// on the fly — no block cache, no read syscall, the kernel page
    /// cache is the only copy. Per-window checksums still validate on
    /// first touch per reader, and corruption quarantines exactly like
    /// the cached path. The serve tier defaults to this.
    Mmap,
}

impl ReadPath {
    /// Parse a CLI/env spelling (`mmap` | `cached`).
    pub fn parse(s: &str) -> Option<ReadPath> {
        match s {
            "mmap" => Some(ReadPath::Mmap),
            "cached" => Some(ReadPath::Cached),
            _ => None,
        }
    }
}

/// Engine construction knobs (config key `pipeline.query_cache_bytes`,
/// CLI `--cache-mb` / `--threads`).
#[derive(Clone, Copy, Debug)]
pub struct QueryOptions {
    /// Block-cache budget, bytes.
    pub cache_bytes: u64,
    /// Cache shard count (contention knob, not capacity).
    pub shards: usize,
    /// Width cap for fanned-out queries: how many slots of the shared
    /// host-pool budget one query may draw (not a thread count).
    pub workers: usize,
    /// Spatial-grid cell sides `[sx, sy, sz]` for the engine's
    /// [`GridIndex`]; `None` → [`CellGrid::default_for`] (~8 cells per
    /// axis). CLI `--cells`.
    pub cell: Option<[usize; 3]>,
    /// Window read path (`PDFFLOW_READ_PATH=mmap|cached` overrides).
    pub read_path: ReadPath,
}

impl Default for QueryOptions {
    fn default() -> Self {
        QueryOptions {
            cache_bytes: 64 << 20,
            shards: 8,
            workers: hostpool::default_budget(),
            cell: None,
            read_path: ReadPath::default(),
        }
    }
}

/// The serving layer: point lookups, region scans and analytical
/// queries over an open [`PdfStore`]. All methods take `&self`, so one
/// engine is shared across query threads.
pub struct QueryEngine {
    store: PdfStore,
    cache: ShardedLru,
    /// Fan-out stage executor on the shared host pool (the ROADMAP
    /// follow-up that replaced the old per-call scoped `util::pool`).
    exec: Executor,
    /// Cell-side override for the spatial index (`QueryOptions::cell`).
    cell: Option<[usize; 3]>,
    /// Lazily built spatial grid index, keyed by the store epoch so a
    /// quarantine invalidates it — first spatial query per epoch pays
    /// the (cheap, catalog-only) build; point/region paths never do.
    index: Mutex<Option<(u64, Arc<GridIndex>)>>,
    /// Which physical path serves window payloads (see [`ReadPath`]).
    read_path: ReadPath,
    /// Reads served zero-copy out of segment mappings.
    ctr_mmap: Arc<crate::telemetry::Counter>,
    /// Reads served through the block cache (hits and fills).
    ctr_cached: Arc<crate::telemetry::Counter>,
}

impl QueryEngine {
    pub fn new(store: PdfStore, opts: QueryOptions) -> QueryEngine {
        let read_path = match std::env::var("PDFFLOW_READ_PATH").ok().as_deref() {
            Some(s) => ReadPath::parse(s).unwrap_or(opts.read_path),
            None => opts.read_path,
        };
        let reg = crate::telemetry::Registry::global();
        QueryEngine {
            store,
            cache: ShardedLru::new(opts.cache_bytes, opts.shards),
            exec: Executor::new(opts.workers.max(1)),
            cell: opts.cell,
            index: Mutex::new(None),
            read_path,
            ctr_mmap: reg.counter("store.read_path.mmap"),
            ctr_cached: reg.counter("store.read_path.cached"),
        }
    }

    /// Open the store's most recently updated run.
    pub fn open(dir: impl AsRef<Path>, opts: QueryOptions) -> Result<QueryEngine> {
        Ok(QueryEngine::new(PdfStore::open(dir)?, opts))
    }

    /// Open a named run of the store (`pdfflow query --run`).
    pub fn open_run(
        dir: impl AsRef<Path>,
        sel: RunSelector,
        opts: QueryOptions,
    ) -> Result<QueryEngine> {
        Ok(QueryEngine::new(PdfStore::open_run(dir, sel)?, opts))
    }

    pub fn store(&self) -> &PdfStore {
        &self.store
    }

    pub fn dims(&self) -> CubeDims {
        self.store.dims()
    }

    pub fn meters(&self) -> CacheMeters {
        self.cache.meters()
    }

    pub fn clear_cache(&self) {
        self.cache.clear()
    }

    /// The read path this engine resolved to (after the env override).
    pub fn read_path(&self) -> ReadPath {
        self.read_path
    }

    /// Shared failed-read bookkeeping for both read paths: a checksum
    /// failure (`Format`) quarantines the whole segment — its other
    /// windows can no longer be trusted — and drops the block cache so
    /// stale blocks of the bad segment cannot be served; the caller's
    /// [`Self::with_fallback`] wrapper then re-runs the query against
    /// the re-resolved (fallback) view.
    fn note_read_error(&self, seg_idx: usize, e: PdfflowError) -> PdfflowError {
        if matches!(e, PdfflowError::Format(_))
            && self.store.quarantine_segment(seg_idx, &e.to_string())
        {
            self.cache.clear();
        }
        e
    }

    /// Fetch one window block, through whichever path [`ReadPath`]
    /// selects. The mmap path decodes straight out of the file mapping
    /// (kernel page cache is the only byte copy) and falls through to
    /// the cached path when no mapping is available.
    fn block(&self, seg_idx: usize, win_idx: usize) -> Result<Arc<Vec<PdfRecord>>> {
        #[cfg(all(feature = "mmap", unix))]
        if self.read_path == ReadPath::Mmap {
            let mapped = self
                .store
                .reader(seg_idx)
                .ok()
                .and_then(|r| r.mmap_window(win_idx));
            if let Some(res) = mapped {
                return match res {
                    Ok(records) => {
                        self.ctr_mmap.inc();
                        Ok(Arc::new(records))
                    }
                    Err(e) => Err(self.note_read_error(seg_idx, e)),
                };
            }
        }
        let key = (seg_idx as u32, win_idx as u32);
        if let Some(b) = self.cache.get(&key) {
            self.ctr_cached.inc();
            return Ok(b);
        }
        match self.store.reader(seg_idx).and_then(|r| r.read_window(win_idx)) {
            Ok(records) => {
                self.ctr_cached.inc();
                let block = Arc::new(records);
                self.cache.put(key, Arc::clone(&block));
                Ok(block)
            }
            Err(e) => Err(self.note_read_error(seg_idx, e)),
        }
    }

    /// Run a query closure; when it fails *and* a quarantine advanced
    /// the store epoch mid-query, re-run it against the re-resolved
    /// view (newest surviving generation first). Bounded by the segment
    /// count — each retry consumes at least one fresh quarantine, so
    /// this cannot loop.
    fn with_fallback<T>(&self, f: impl Fn() -> Result<T>) -> Result<T> {
        let mut tries = 0usize;
        loop {
            let epoch = self.store.epoch();
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    tries += 1;
                    if self.store.epoch() == epoch || tries > self.store.n_segments() + 1 {
                        return Err(e);
                    }
                }
            }
        }
    }

    /// Typed error when any slice in `[z0, z1]` lost coverage to a
    /// quarantine — box-shaped queries skip never-persisted slices by
    /// design, so without this check an unresolvable slice would read
    /// as a silently smaller answer.
    fn check_resolvable(&self, z0: usize, z1: usize) -> Result<()> {
        if let Some((z, why)) = self.store.unresolvable_in(z0, z1) {
            return Err(PdfflowError::Format(format!(
                "slice {z} is unresolvable: {why}"
            )));
        }
        Ok(())
    }

    /// Point lookup by coordinates.
    pub fn point(&self, x: usize, y: usize, z: usize) -> Result<PdfRecord> {
        self.with_fallback(|| self.point_inner(x, y, z))
    }

    fn point_inner(&self, x: usize, y: usize, z: usize) -> Result<PdfRecord> {
        let dims = self.dims();
        if x >= dims.nx || y >= dims.ny || z >= dims.nz {
            return Err(PdfflowError::InvalidArg(format!(
                "point ({x},{y},{z}) outside {}x{}x{} cube",
                dims.nx, dims.ny, dims.nz
            )));
        }
        let part = self.store.find_part(z, y)?.ok_or_else(|| {
            PdfflowError::InvalidArg(format!(
                "slice {z} line {y} is not persisted in run {}",
                self.store.run_key().label()
            ))
        })?;
        // Window order == point-id order: the offset is pure arithmetic.
        let idx = (y - part.entry.y0 as usize) * dims.nx + x;
        // Point fast path: one 28-byte decode out of the mapping (the
        // whole window is checksummed on its first touch), skipping the
        // block cache and the whole-window decode entirely.
        #[cfg(all(feature = "mmap", unix))]
        if self.read_path == ReadPath::Mmap {
            let mapped = self
                .store
                .reader(part.seg)
                .ok()
                .and_then(|r| r.mmap_record(part.win, idx));
            if let Some(res) = mapped {
                let rec = res.map_err(|e| self.note_read_error(part.seg, e))?;
                self.ctr_mmap.inc();
                if rec.point != dims.point_id(x, y, z) {
                    return Err(PdfflowError::Format(format!(
                        "store row mismatch: expected point {:?}, found {:?}",
                        dims.point_id(x, y, z),
                        rec.point
                    )));
                }
                return Ok(rec);
            }
        }
        let block = self.block(part.seg, part.win)?;
        let rec = block.get(idx).copied().ok_or_else(|| {
            PdfflowError::Format(format!(
                "window block of slice {z} line {y} holds {} records, wanted index {idx}",
                block.len()
            ))
        })?;
        if rec.point != dims.point_id(x, y, z) {
            return Err(PdfflowError::Format(format!(
                "store row mismatch: expected point {:?}, found {:?}",
                dims.point_id(x, y, z),
                rec.point
            )));
        }
        Ok(rec)
    }

    /// Point lookup by flat id.
    pub fn point_by_id(&self, id: PointId) -> Result<PdfRecord> {
        let (x, y, z) = self.dims().coords(id);
        self.point(x, y, z)
    }

    /// Batched point lookups, fanned out over the engine's worker
    /// threads; output order matches input order.
    pub fn points(&self, ids: &[PointId]) -> Result<Vec<PdfRecord>> {
        let chunk = ids.len().div_ceil(self.exec.threads()).max(1);
        let chunks: Vec<&[PointId]> = ids.chunks(chunk).collect();
        let results = self.exec.try_run(chunks, |chunk| {
            chunk
                .iter()
                .map(|&id| self.point_by_id(id))
                .collect::<Result<Vec<PdfRecord>>>()
        })?;
        let mut out = Vec::with_capacity(ids.len());
        for r in results {
            out.extend(r);
        }
        Ok(out)
    }

    /// Resolved windows of slice `z` overlapping line range [y0, y1] —
    /// in y0 order, which is what keeps parallel merges deterministic.
    fn region_parts(&self, q: &RegionQuery) -> Result<Vec<SlicePart>> {
        let parts = self.store.slice_parts(q.z)?.ok_or_else(|| {
            PdfflowError::InvalidArg(format!(
                "slice {} is not persisted in run {}",
                q.z,
                self.store.run_key().label()
            ))
        })?;
        Ok(parts
            .iter()
            .filter(|p| {
                let (lo, hi) = (p.entry.y0 as usize, (p.entry.y0 + p.entry.lines) as usize);
                hi > q.y0 && lo <= q.y1
            })
            .copied()
            .collect())
    }

    /// Parallel filtered scan over resolved windows: records inside the
    /// box, concatenated in the order `wins` was given. Every caller
    /// passes windows ascending `(z, y0)`, so output is point-id order
    /// and identical at any thread count.
    fn scan_windows(&self, wins: Vec<SlicePart>, b: BoxQuery) -> Result<Vec<PdfRecord>> {
        let dims = self.dims();
        let parts = self.exec.try_run(wins, |part| -> Result<Vec<PdfRecord>> {
            let block = self.block(part.seg, part.win)?;
            Ok(block
                .iter()
                .filter(|rec| {
                    let (x, y, z) = dims.coords(rec.point);
                    b.contains(x, y, z)
                })
                .copied()
                .collect())
        })?;
        let mut out = Vec::new();
        for p in parts {
            out.extend(p);
        }
        Ok(out)
    }

    /// Parallel analytical scan: per-window partials merged in the order
    /// `wins` was given (the module-level determinism contract — see
    /// [`crate::spatial`]).
    fn summarize_windows(&self, wins: Vec<SlicePart>, b: BoxQuery) -> Result<RegionSummary> {
        let dims = self.dims();
        struct Partial {
            n: usize,
            err_sum: f64,
            err_max: f64,
            types: [u64; 10],
            hist: [u64; ERROR_HIST_BINS],
        }
        let parts = self.exec.try_run(wins, |part| -> Result<Partial> {
            let block = self.block(part.seg, part.win)?;
            let mut p = Partial {
                n: 0,
                err_sum: 0.0,
                err_max: 0.0,
                types: [0; 10],
                hist: [0; ERROR_HIST_BINS],
            };
            for rec in block.iter() {
                let (x, y, z) = dims.coords(rec.point);
                if !b.contains(x, y, z) {
                    continue;
                }
                p.n += 1;
                let e = rec.error as f64;
                p.err_sum += e;
                p.err_max = p.err_max.max(e);
                p.types[rec.dist.id()] += 1;
                let bin = ((e / PENALTY_ERROR) * ERROR_HIST_BINS as f64).floor();
                p.hist[(bin.max(0.0) as usize).min(ERROR_HIST_BINS - 1)] += 1;
            }
            Ok(p)
        })?;
        let mut s = RegionSummary::empty();
        let mut err_sum = 0.0;
        for p in parts {
            s.n_points += p.n;
            err_sum += p.err_sum;
            s.max_error = s.max_error.max(p.err_max);
            for i in 0..10 {
                s.type_counts[i] += p.types[i];
            }
            for i in 0..ERROR_HIST_BINS {
                s.error_hist[i] += p.hist[i];
            }
        }
        if s.n_points > 0 {
            s.avg_error = err_sum / s.n_points as f64;
        }
        Ok(s)
    }

    /// One slice's inclusive rectangle as a 3D box.
    fn region_box(q: &RegionQuery) -> BoxQuery {
        BoxQuery {
            x0: q.x0,
            x1: q.x1,
            y0: q.y0,
            y1: q.y1,
            z0: q.z,
            z1: q.z,
        }
    }

    /// Rectangular region scan: all records with x0≤x≤x1, y0≤y≤y1 on
    /// slice z, in point-id order. Window blocks are fetched in parallel.
    pub fn region(&self, q: &RegionQuery) -> Result<Vec<PdfRecord>> {
        self.with_fallback(|| {
            let wins = self.region_parts(q)?;
            self.scan_windows(wins, Self::region_box(q))
        })
    }

    /// Analytical region query: error statistics + type/error histograms.
    /// Per-window partials are computed in parallel and merged in window
    /// order, so the result is identical at any thread count.
    pub fn region_summary(&self, q: &RegionQuery) -> Result<RegionSummary> {
        self.with_fallback(|| {
            let wins = self.region_parts(q)?;
            self.summarize_windows(wins, Self::region_box(q))
        })
    }

    /// The engine's spatial grid index for the current store epoch,
    /// built lazily from the catalog's resolved view (no payload
    /// reads); rebuilt after a quarantine re-resolves the store.
    pub fn spatial_index(&self) -> Arc<GridIndex> {
        let epoch = self.store.epoch();
        let mut guard = self.index.lock().unwrap();
        if let Some((built_at, idx)) = guard.as_ref() {
            if *built_at == epoch {
                return Arc::clone(idx);
            }
        }
        let grid = match self.cell {
            Some([sx, sy, sz]) => CellGrid::new(self.dims(), sx, sy, sz),
            None => CellGrid::default_for(self.dims()),
        };
        let idx = Arc::new(GridIndex::build(&self.store, grid));
        *guard = Some((epoch, Arc::clone(&idx)));
        idx
    }

    /// Index-pruned candidate windows of a box, ascending `(z, y0)`.
    fn box_parts(&self, q: &BoxQuery) -> Vec<SlicePart> {
        self.spatial_index()
            .parts_for_box(q)
            .into_iter()
            .map(|(_, p)| p)
            .collect()
    }

    /// True 3D box scan: all records inside the box, point-id order.
    /// Unlike [`region`](Self::region), slices the run never persisted
    /// are skipped, not an error — a 3D box queries the resolved view,
    /// whatever subset of the cube it covers.
    pub fn box_records(&self, q: &BoxQuery) -> Result<Vec<PdfRecord>> {
        self.with_fallback(|| {
            self.check_resolvable(q.z0, q.z1)?;
            self.scan_windows(self.box_parts(q), *q)
        })
    }

    /// Analytical summary of a 3D box (same statistics as a region
    /// summary, computed over the box's resolved records).
    pub fn box_summary(&self, q: &BoxQuery) -> Result<RegionSummary> {
        self.with_fallback(|| {
            self.check_resolvable(q.z0, q.z1)?;
            self.summarize_windows(self.box_parts(q), *q)
        })
    }

    /// Radius query: records within Euclidean `radius` of the center
    /// (point-index units), point-id order. Pruned to the ball's
    /// bounding box via the index; the membership predicate is the
    /// exact integer squared distance against `radius²`.
    pub fn radius_records(&self, q: &RadiusQuery) -> Result<Vec<PdfRecord>> {
        let dims = self.dims();
        if q.radius < 0.0 {
            return Ok(Vec::new());
        }
        self.with_fallback(|| {
            let b = q.bounding_box(&dims);
            self.check_resolvable(b.z0, b.z1)?;
            let wins = self.box_parts(&b);
            let r2 = q.radius * q.radius;
            let center = (q.x, q.y, q.z);
            let records = self.scan_windows(wins, b)?;
            Ok(records
                .into_iter()
                .filter(|rec| dist2(dims.coords(rec.point), center) as f64 <= r2)
                .collect())
        })
    }

    /// k nearest stored records around a point, ordered by `(squared
    /// distance, PointId)` — ties always break toward the lower point
    /// id. Searches an expanding Chebyshev box through the index,
    /// stopping once the k-th candidate provably beats everything
    /// outside the box (points beyond a half-width `h` box are at
    /// squared distance > h², so they can neither displace nor tie).
    pub fn knn(&self, q: &KnnQuery) -> Result<Vec<PdfRecord>> {
        let dims = self.dims();
        self.with_fallback(|| {
            // The expanding search may touch any slice; any lost
            // coverage could change the answer silently.
            self.check_resolvable(0, dims.nz.saturating_sub(1))?;
            let k = q.k.min(self.store.n_records() as usize);
            if k == 0 {
                return Ok(Vec::new());
            }
            let center = (q.x, q.y, q.z);
            let grid = self.spatial_index().grid();
            let whole = BoxQuery::whole(&dims);
            let mut half = grid.sx.max(grid.sy).max(grid.sz);
            loop {
                let b = BoxQuery::around(&dims, center, half);
                let mut cand = self.scan_windows(self.box_parts(&b), b)?;
                cand.sort_unstable_by_key(|rec| (dist2(dims.coords(rec.point), center), rec.point));
                let settled = cand.len() >= k
                    && dist2(dims.coords(cand[k - 1].point), center) <= half as u64 * half as u64;
                if settled || b == whole {
                    cand.truncate(k);
                    return Ok(cand);
                }
                half *= 2;
            }
        })
    }

    /// Per-cell aggregation of fit outcomes over a box: dominant
    /// distribution type, mean Eq. 5 error and max error per grid cell,
    /// plus the type-transition boundary cells. Parallel per window,
    /// merged in window order (thread-count invariant).
    pub fn cell_aggregate(&self, q: &BoxQuery) -> Result<SpatialAggregate> {
        self.with_fallback(|| self.cell_aggregate_inner(q))
    }

    fn cell_aggregate_inner(&self, q: &BoxQuery) -> Result<SpatialAggregate> {
        self.check_resolvable(q.z0, q.z1)?;
        let dims = self.dims();
        let grid = self.spatial_index().grid();
        let wins = self.box_parts(q);
        let q = *q;
        #[derive(Clone, Copy)]
        struct Acc {
            n: usize,
            types: [u64; 10],
            err_sum: f64,
            max: f32,
        }
        const ZERO: Acc = Acc {
            n: 0,
            types: [0; 10],
            err_sum: 0.0,
            max: 0.0,
        };
        let parts = self.exec.try_run(wins, |part| -> Result<BTreeMap<usize, Acc>> {
            let block = self.block(part.seg, part.win)?;
            let mut m: BTreeMap<usize, Acc> = BTreeMap::new();
            for rec in block.iter() {
                let (x, y, z) = dims.coords(rec.point);
                if !q.contains(x, y, z) {
                    continue;
                }
                let a = m.entry(grid.cell_index(grid.cell_of(x, y, z))).or_insert(ZERO);
                a.n += 1;
                a.types[rec.dist.id()] += 1;
                a.err_sum += rec.error as f64;
                a.max = a.max.max(rec.error);
            }
            Ok(m)
        })?;
        let mut cells: BTreeMap<usize, Acc> = BTreeMap::new();
        for m in parts {
            for (idx, w) in m {
                let a = cells.entry(idx).or_insert(ZERO);
                a.n += w.n;
                for i in 0..10 {
                    a.types[i] += w.types[i];
                }
                a.err_sum += w.err_sum;
                a.max = a.max.max(w.max);
            }
        }
        let summaries: Vec<CellSummary> = cells
            .iter()
            .map(|(&idx, a)| CellSummary {
                cell: grid.cell_at(idx),
                n_points: a.n,
                type_counts: a.types,
                dominant: dominant_type(&a.types),
                err_sum: a.err_sum,
                max_error: a.max,
            })
            .collect();
        let boundary = Self::boundary_of(&grid, &summaries);
        Ok(SpatialAggregate {
            grid,
            cells: summaries,
            boundary,
        })
    }

    /// Type-transition boundary cells: non-empty cells with a non-empty
    /// 6-neighbor of a different dominant type, ascending cell index
    /// (independent twin of `spatial::oracle::boundary_cells`).
    fn boundary_of(grid: &CellGrid, cells: &[CellSummary]) -> Vec<(usize, usize, usize)> {
        let dom: std::collections::HashMap<(usize, usize, usize), usize> =
            cells.iter().map(|c| (c.cell, c.dominant.id())).collect();
        let (ncx, ncy, ncz) = (grid.ncx(), grid.ncy(), grid.ncz());
        let mut out = Vec::new();
        for c in cells {
            let (cx, cy, cz) = c.cell;
            let neighbor = |dx: isize, dy: isize, dz: isize| -> Option<(usize, usize, usize)> {
                let (nx, ny, nz) = (cx as isize + dx, cy as isize + dy, cz as isize + dz);
                (nx >= 0 && ny >= 0 && nz >= 0)
                    .then_some((nx as usize, ny as usize, nz as usize))
                    .filter(|&(a, b, c)| a < ncx && b < ncy && c < ncz)
            };
            let me = c.dominant.id();
            let deltas: [(isize, isize, isize); 6] =
                [(-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)];
            if deltas.iter().any(|&(dx, dy, dz)| {
                neighbor(dx, dy, dz)
                    .and_then(|n| dom.get(&n))
                    .is_some_and(|&d| d != me)
            }) {
                out.push(c.cell);
            }
        }
        out
    }

    /// Cross-run diff over a box: this engine is side A, `other` side B
    /// (each opened through the generational catalog — `open_run` with
    /// any [`RunSelector`]). Compares fitted type/error maps point by
    /// point; deltas accumulate in point-id order (thread invariant).
    pub fn diff_run(&self, other: &QueryEngine, q: &BoxQuery) -> Result<RunDiff> {
        let dims = self.dims();
        if other.dims() != dims {
            return Err(PdfflowError::InvalidArg(format!(
                "diff across different cubes: {}x{}x{} vs {}x{}x{}",
                dims.nx,
                dims.ny,
                dims.nz,
                other.dims().nx,
                other.dims().ny,
                other.dims().nz
            )));
        }
        let grid = self.spatial_index().grid();
        let a = self.box_records(q)?;
        let b = other.box_records(q)?;
        let mut d = RunDiff {
            n_compared: 0,
            only_a: 0,
            only_b: 0,
            type_changed: 0,
            type_counts_a: [0; 10],
            type_counts_b: [0; 10],
            err_delta_sum: 0.0,
            max_err_delta: 0.0,
            changed_cells: Vec::new(),
            grid,
        };
        let mut changed: BTreeSet<usize> = BTreeSet::new();
        let (mut i, mut j) = (0usize, 0usize);
        // Both sides are in ascending point-id order: a linear merge join.
        while i < a.len() && j < b.len() {
            match a[i].point.cmp(&b[j].point) {
                std::cmp::Ordering::Less => {
                    d.only_a += 1;
                    i += 1;
                }
                std::cmp::Ordering::Greater => {
                    d.only_b += 1;
                    j += 1;
                }
                std::cmp::Ordering::Equal => {
                    let (ra, rb) = (a[i], b[j]);
                    d.n_compared += 1;
                    d.type_counts_a[ra.dist.id()] += 1;
                    d.type_counts_b[rb.dist.id()] += 1;
                    let delta = (ra.error - rb.error).abs();
                    d.err_delta_sum += delta as f64;
                    d.max_err_delta = d.max_err_delta.max(delta);
                    if ra.dist != rb.dist {
                        d.type_changed += 1;
                        let (x, y, z) = dims.coords(ra.point);
                        changed.insert(grid.cell_index(grid.cell_of(x, y, z)));
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
        d.only_a += a.len() - i;
        d.only_b += b.len() - j;
        d.changed_cells = changed.into_iter().map(|idx| grid.cell_at(idx)).collect();
        Ok(d)
    }

    /// Density of a stored PDF at `x` (the paper's §1 deliverable shape).
    pub fn density_at(&self, rec: &PdfRecord, x: f64) -> f64 {
        let fit = rec.fit();
        density::pdf(fit.dist, &fit.params, x)
    }

    /// CDF of a stored PDF at `x`.
    pub fn cdf_at(&self, rec: &PdfRecord, x: f64) -> f64 {
        let fit = rec.fit();
        stats::cdf(fit.dist, &fit.params, x)
    }

    /// Quantile `p` of a stored PDF (inverse CDF via `stats`).
    pub fn quantile_of(&self, rec: &PdfRecord, p: f64) -> f64 {
        let fit = rec.fit();
        density::quantile(fit.dist, &fit.params, p)
    }

    /// Mean of the per-point quantile-`p` values over a region — e.g.
    /// "the median velocity surface of this block". Parallel per window,
    /// merged in window order (thread-count invariant).
    pub fn region_quantile_mean(&self, q: &RegionQuery, p: f64) -> Result<f64> {
        self.with_fallback(|| self.region_quantile_mean_inner(q, p))
    }

    fn region_quantile_mean_inner(&self, q: &RegionQuery, p: f64) -> Result<f64> {
        let dims = self.dims();
        let wins = self.region_parts(q)?;
        let q = *q;
        let parts = self.exec.try_run(wins, |part| -> Result<(usize, f64)> {
            let block = self.block(part.seg, part.win)?;
            let mut n = 0usize;
            let mut sum = 0.0f64;
            for rec in block.iter() {
                let (x, y, _) = dims.coords(rec.point);
                if x < q.x0 || x > q.x1 || y < q.y0 || y > q.y1 {
                    continue;
                }
                let fit = rec.fit();
                sum += density::quantile(fit.dist, &fit.params, p);
                n += 1;
            }
            Ok((n, sum))
        })?;
        let mut n = 0usize;
        let mut sum = 0.0f64;
        for (pn, ps) in parts {
            n += pn;
            sum += ps;
        }
        if n == 0 {
            return Err(PdfflowError::InvalidArg("empty region".into()));
        }
        Ok(sum / n as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::DistType;

    fn rec(i: u64) -> PdfRecord {
        PdfRecord {
            point: PointId(i),
            dist: DistType::Normal,
            error: 0.1,
            params: [0.0, 1.0, 0.0],
        }
    }

    fn block_of(n: usize) -> Arc<Vec<PdfRecord>> {
        Arc::new((0..n as u64).map(rec).collect())
    }

    #[test]
    fn sharded_lru_hit_miss_eviction_meters() {
        // One shard so eviction order is easy to reason about; each
        // 10-record block is 280 bytes, budget fits two.
        let c = ShardedLru::new(600, 1);
        assert!(c.get(&(0, 0)).is_none());
        c.put((0, 0), block_of(10));
        c.put((0, 1), block_of(10));
        assert!(c.get(&(0, 0)).is_some()); // refresh 0 → 1 is LRU
        c.put((0, 2), block_of(10)); // evicts (0,1)
        assert!(c.get(&(0, 1)).is_none());
        assert!(c.get(&(0, 2)).is_some());
        let m = c.meters();
        assert_eq!(m.evictions, 1);
        assert_eq!(m.hits, 2);
        assert_eq!(m.misses, 2);
        assert_eq!(m.entries, 2);
        assert_eq!(m.bytes, 2 * 280);
    }

    #[test]
    fn oversized_blocks_are_not_cached() {
        let c = ShardedLru::new(100, 4); // 25 bytes per shard
        c.put((0, 0), block_of(10));
        assert!(c.get(&(0, 0)).is_none());
        assert_eq!(c.meters().entries, 0);
    }

    #[test]
    fn clear_keeps_counters_but_drops_blocks() {
        let c = ShardedLru::new(1 << 20, 4);
        c.put((0, 0), block_of(5));
        assert!(c.get(&(0, 0)).is_some());
        c.clear();
        assert!(c.get(&(0, 0)).is_none());
        let m = c.meters();
        assert_eq!((m.bytes, m.entries), (0, 0));
        assert_eq!(m.hits, 1);
        assert_eq!(m.misses, 1);
    }

    #[test]
    fn region_query_counts() {
        let q = RegionQuery { z: 0, x0: 2, x1: 4, y0: 1, y1: 2 };
        assert_eq!(q.n_points(), 6);
        let dims = CubeDims::new(8, 5, 3);
        let full = RegionQuery::slice(&dims, 2);
        assert_eq!(full.n_points(), 40);
    }
}
