//! Store scrub: offline corruption sweep and repair.
//!
//! `pdfflow store scrub [--repair]` walks **every run** in the catalog,
//! full-payload-verifies every segment ([`PdfStore::verify_report`] —
//! the same checksums the read path enforces window-by-window),
//! quarantines each failure, and reports per run what survives:
//!
//! * **bad** segments — checksum or open failures, quarantined;
//! * **unresolvable** slices — coverage the surviving generations can
//!   no longer prove (those reads are typed errors until re-persisted);
//! * with `--repair`, salvageable runs (bad segments present, no
//!   coverage lost) are rewritten through the compaction path
//!   (`compact::rewrite_resolved` + `compact::publish_run`): the
//!   resolved fallback view — bit-identical to what queries serve —
//!   becomes one dense new generation, and the corrupt files are
//!   retired with the rest of the superseded generations.
//!
//! Scrub never deletes data it cannot re-derive: a run with lost
//! coverage is reported, not rewritten, so the damaged files stay on
//! disk for forensics or a re-run of the pipeline.

use std::path::Path;

use crate::pdfstore::compact::{publish_run, rewrite_resolved};
use crate::pdfstore::{Catalog, PdfStore, RunKey, RunSelector};
use crate::Result;

/// One segment's scrub outcome (mirrors [`super::SegmentVerify`], owned
/// by run so the report serializes flat).
#[derive(Clone, Debug)]
pub struct ScrubSegment {
    pub file: String,
    pub slice: usize,
    pub gen: usize,
    /// `None` = checksums good; otherwise why the segment is bad.
    pub error: Option<String>,
}

/// Scrub outcome of one run.
#[derive(Clone, Debug)]
pub struct ScrubRun {
    pub run: RunKey,
    pub segments: Vec<ScrubSegment>,
    /// Segments that failed verification (all quarantined).
    pub bad: usize,
    /// Slices whose coverage the surviving generations cannot prove,
    /// with the reason. Non-empty blocks repair.
    pub unresolvable: Vec<(usize, String)>,
    /// True when `--repair` rewrote this run to a fresh generation.
    pub repaired: bool,
    /// Generation the repair published, when it ran.
    pub repaired_gen: Option<usize>,
    /// Superseded files (corrupt ones included) unlinked by the repair.
    pub retired_files: usize,
}

/// Whole-catalog scrub report.
#[derive(Clone, Debug, Default)]
pub struct ScrubReport {
    pub runs: Vec<ScrubRun>,
}

impl ScrubReport {
    /// Bad segments across every run.
    pub fn total_bad(&self) -> usize {
        self.runs.iter().map(|r| r.bad).sum()
    }

    /// True when every segment of every run verified clean.
    pub fn all_ok(&self) -> bool {
        self.total_bad() == 0
    }

    /// True when damage remains after this scrub: bad segments that were
    /// not repaired away, or coverage that repair could not restore.
    pub fn needs_attention(&self) -> bool {
        self.runs
            .iter()
            .any(|r| (r.bad > 0 && !r.repaired) || !r.unresolvable.is_empty())
    }

    /// Multi-line CLI listing, one block per run.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for r in &self.runs {
            out.push_str(&format!(
                "run {}: {} segment(s), {} bad\n",
                r.run.label(),
                r.segments.len(),
                r.bad
            ));
            for s in &r.segments {
                match &s.error {
                    None => out.push_str(&format!(
                        "  ok  {} (slice {}, gen {})\n",
                        s.file, s.slice, s.gen
                    )),
                    Some(e) => out.push_str(&format!(
                        "  BAD {} (slice {}, gen {}): {e}\n",
                        s.file, s.slice, s.gen
                    )),
                }
            }
            for (z, why) in &r.unresolvable {
                out.push_str(&format!("  slice {z} UNRESOLVABLE: {why}\n"));
            }
            if r.repaired {
                out.push_str(&format!(
                    "  repaired -> generation {} ({} file(s) retired)\n",
                    r.repaired_gen.unwrap_or(0),
                    r.retired_files
                ));
            } else if r.bad > 0 {
                out.push_str(if r.unresolvable.is_empty() {
                    "  salvageable: older generations cover every line (rerun with --repair)\n"
                } else {
                    "  NOT salvageable: coverage lost; re-persist the run\n"
                });
            }
        }
        out
    }
}

/// Scrub every run in the store at `dir` (see module docs). With
/// `repair`, salvageable runs are rewritten via the compaction path;
/// without it, the sweep is read-only.
pub fn scrub_store(dir: impl AsRef<Path>, repair: bool) -> Result<ScrubReport> {
    let dir = dir.as_ref();
    let keys: Vec<RunKey> = Catalog::load(dir)?
        .runs
        .iter()
        .map(|r| r.key.clone())
        .collect();
    let mut report = ScrubReport::default();
    for key in keys {
        // Tolerant open: a run a strict open would reject (coverage
        // already lost) is exactly what scrub must be able to report.
        let store = PdfStore::open_run_tolerant(dir, RunSelector::Key(&key))?;
        let verify = store.verify_report();
        for s in &verify.segments {
            if let Some(e) = &s.error {
                store.quarantine_segment(s.idx, e);
            }
        }
        let segments: Vec<ScrubSegment> = verify
            .segments
            .iter()
            .map(|s| ScrubSegment {
                file: s.file.clone(),
                slice: s.slice,
                gen: s.gen,
                error: s.error.clone(),
            })
            .collect();
        let bad = verify.n_bad();
        let unresolvable = store.unresolvable_slices();
        let mut run = ScrubRun {
            run: key.clone(),
            segments,
            bad,
            unresolvable,
            repaired: false,
            repaired_gen: None,
            retired_files: 0,
        };
        if repair && bad > 0 && run.unresolvable.is_empty() {
            // The resolved fallback view is fully covered — materialize
            // it as a fresh dense generation, exactly as compaction
            // would, then retire every superseded file (the corrupt
            // ones among them).
            let new_gen = store.run().max_gen().map(|g| g + 1).unwrap_or(0);
            let old_files: Vec<String> =
                store.run().segments.iter().map(|s| s.file.clone()).collect();
            let new_metas = rewrite_resolved(dir, &store, new_gen)?;
            drop(store);
            run.retired_files = publish_run(dir, &key, new_metas, &old_files)?;
            run.repaired = true;
            run.repaired_gen = Some(new_gen);
        }
        report.runs.push(run);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cube::{CubeDims, PointId};
    use crate::pdfstore::{PdfRecord, StoreWriter};
    use crate::stats::DistType;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("pdfflow-scrub-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn records(base: u64, n: u64) -> Vec<PdfRecord> {
        (0..n)
            .map(|i| PdfRecord {
                point: PointId(base + i),
                dist: DistType::Normal,
                error: 0.5,
                params: [1.0, 2.0, 0.0],
            })
            .collect()
    }

    /// Two generations of one slice: gen 0 covers lines 0..4, gen 1
    /// rewrites the same lines. Returns the store dir.
    fn two_gen_store(tag: &str) -> std::path::PathBuf {
        let dir = tmp(tag);
        let dims = CubeDims::new(4, 4, 2);
        let mut w = StoreWriter::create(&dir, dims, 16).unwrap();
        let key = RunKey::new("baseline", 4, "default");
        for _gen in 0..2 {
            let mut sw = w.open_segment(1, &key).unwrap();
            sw.append_records(0, 4, &records(100, 16)).unwrap();
            let meta = sw.finish().unwrap();
            w.add_segment(meta).unwrap();
        }
        dir
    }

    fn flip_payload_byte(path: &std::path::Path) {
        let mut bytes = std::fs::read(path).unwrap();
        bytes[16] ^= 0x01; // inside the first record, after the header
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn clean_store_scrubs_clean() {
        let dir = two_gen_store("clean");
        let report = scrub_store(&dir, false).unwrap();
        assert!(report.all_ok(), "{}", report.render());
        assert!(!report.needs_attention());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn scrub_reports_then_repairs_a_corrupt_generation() {
        let dir = two_gen_store("repair");
        flip_payload_byte(&dir.join("slice1_baseline_4_default_g1.seg"));

        // Read-only sweep: finds the bad segment, changes nothing.
        let report = scrub_store(&dir, false).unwrap();
        assert_eq!(report.total_bad(), 1, "{}", report.render());
        let r = &report.runs[0];
        assert!(!r.repaired);
        assert!(r.unresolvable.is_empty(), "gen 0 still covers the lines");
        assert!(report.needs_attention());

        // Repair: the surviving gen-0 view becomes a fresh generation
        // and both old files are retired.
        let report = scrub_store(&dir, true).unwrap();
        let r = &report.runs[0];
        assert!(r.repaired, "{}", report.render());
        assert_eq!(r.repaired_gen, Some(2));
        assert_eq!(r.retired_files, 2);
        assert!(!report.needs_attention());

        // The repaired store is clean, whole, and serves gen 0's bytes.
        let store = PdfStore::open(&dir).unwrap();
        store.verify().unwrap();
        assert_eq!(store.n_segments(), 1);
        assert_eq!(store.n_records(), 16);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lost_coverage_is_reported_not_repaired() {
        let dir = tmp("lost");
        let dims = CubeDims::new(4, 4, 2);
        let mut w = StoreWriter::create(&dir, dims, 16).unwrap();
        let key = RunKey::new("baseline", 4, "default");
        let mut sw = w.open_segment(1, &key).unwrap();
        sw.append_records(0, 4, &records(100, 16)).unwrap();
        let meta = sw.finish().unwrap();
        w.add_segment(meta).unwrap();
        // The only copy of the slice goes bad: nothing to fall back to.
        flip_payload_byte(&dir.join("slice1_baseline_4_default_g0.seg"));

        let report = scrub_store(&dir, true).unwrap();
        let r = &report.runs[0];
        assert_eq!(r.bad, 1, "{}", report.render());
        assert!(!r.repaired, "must not rewrite a run with lost coverage");
        assert_eq!(r.unresolvable.len(), 1);
        assert!(report.needs_attention());
        // The damaged file is left in place for forensics.
        assert!(dir.join("slice1_baseline_4_default_g0.seg").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
