//! Staged task executor: the driver-side scheduler that turns partition
//! and window work into parallel tasks (the Spark-scheduler analog of
//! the paper's §4.2 "parallel execution" principle).
//!
//! The executor runs a *stage*: a vector of independent tasks claimed
//! from a shared work queue by up to `threads` concurrent claim loops
//! (work-stealing by atomic cursor, like the partition task sets the
//! Ripley's-K and random-forest Spark systems schedule per stage). Two
//! contracts make the rest of the system simple:
//!
//! * **Deterministic task → result ordering.** Results are always
//!   delivered in task-index order, never completion order, so every
//!   caller observes the same output at any thread count.
//! * **Fail-fast stages.** A panicking task fails the whole stage (the
//!   panic propagates to the caller after the stage quiesces); a task
//!   returning `Err` cancels the remaining queue and the stage reports
//!   the error of the smallest failing task index.
//!
//! Since the host-pool refactor the executor owns **no threads**: every
//! stage draws from the process-wide [`HostPool`] budget, and `threads`
//! is a *width cap* on how many pool slots the stage may use. Plain
//! stages ([`Executor::run`], [`Executor::try_run`]) are help-first —
//! the calling thread claims tasks alongside the pool workers — so
//! nested stages (an RDD action inside a window task, a backend call
//! inside either) compose without oversubscribing or deadlocking: the
//! total number of live compute threads never exceeds the one budget.
//!
//! [`Executor::run_sequenced`] is the pipelined variant: pool workers
//! compute tasks concurrently while the calling thread consumes results
//! through a *sequenced sink* — a reorder buffer that invokes the
//! consumer strictly in task order. This is how the window pipeline
//! overlaps loading/fitting of window *i+1* with persisting window *i*
//! while the segment writer still sees windows in slice order.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Instant;

use crate::runtime::hostpool::{self, HostPool, PanicPayload};
use crate::Result;

/// Default executor width: the `PDFFLOW_EXECUTOR_THREADS` environment
/// override when set to a positive integer, else the full host budget.
pub fn default_threads() -> usize {
    std::env::var("PDFFLOW_EXECUTOR_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(hostpool::default_budget)
}

/// Per-stage observability: what one executor stage actually did.
/// Deterministic fields (`tasks`) are thread-count invariant; the
/// others are measurements and vary run to run like any timing.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageMetrics {
    /// Tasks executed by the stage.
    pub tasks: u64,
    /// Summed wall-clock seconds spent inside task bodies.
    pub busy_s: f64,
    /// Maximum tasks observed running concurrently.
    pub peak_in_flight: usize,
    /// Deepest reorder buffer (results completed but not yet consumed
    /// in task order) a sequenced stage ever held.
    pub peak_pending: usize,
}

/// Process-wide per-task duration histogram (`executor.task_ns`).
/// Every executor instance feeds it; per-stage assertions stay on the
/// caller-owned [`StageMetrics`].
fn task_hist() -> &'static crate::telemetry::Histogram {
    static HIST: std::sync::OnceLock<Arc<crate::telemetry::Histogram>> = std::sync::OnceLock::new();
    HIST.get_or_init(|| crate::telemetry::Registry::global().histogram("executor.task_ns"))
}

/// Process-wide consumed-task counter (`executor.tasks`).
fn task_counter() -> &'static crate::telemetry::Counter {
    static C: std::sync::OnceLock<Arc<crate::telemetry::Counter>> = std::sync::OnceLock::new();
    C.get_or_init(|| crate::telemetry::Registry::global().counter("executor.tasks"))
}

/// A stage executor with a width cap on the shared host-pool budget.
#[derive(Clone, Debug)]
pub struct Executor {
    threads: usize,
    pool: Arc<HostPool>,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new(default_threads())
    }
}

impl Executor {
    /// An executor running at most `threads` concurrent tasks (clamped
    /// to at least 1) on the global [`HostPool`].
    pub fn new(threads: usize) -> Executor {
        Executor::on_pool(threads, Arc::clone(HostPool::global()))
    }

    /// An executor on an explicit pool (tests pin budgets this way).
    pub fn on_pool(threads: usize, pool: Arc<HostPool>) -> Executor {
        Executor {
            threads: threads.max(1),
            pool,
        }
    }

    /// A single-threaded executor (tasks run inline, in order).
    pub fn sequential() -> Executor {
        Executor::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The pool this executor draws its budget from.
    pub fn pool(&self) -> &Arc<HostPool> {
        &self.pool
    }

    /// Run one stage of infallible tasks; returns results in task order.
    /// A panic in any task propagates to the caller once the stage has
    /// quiesced (the stage fails as a unit). Help-first on the shared
    /// pool: safe to call from anywhere, including inside other stages.
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        self.pool.parallel_map(tasks, self.threads, f)
    }

    /// Run one stage of fallible tasks. On success returns all results
    /// in task order; on failure returns the error of the *smallest*
    /// failing task index (deterministic at any thread count — claims
    /// happen in cursor order, so every task below the first failure
    /// has run) after cancelling the unclaimed remainder of the queue.
    pub fn try_run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> Result<R> + Sync,
    {
        // Cancellation watermark: the smallest failing index seen so
        // far. A task is skipped only when its index is *above* the
        // watermark, so every task below the final smallest failure is
        // guaranteed to have run — which is what makes the reported
        // error deterministic at any width.
        let first_err = AtomicUsize::new(usize::MAX);
        let indexed: Vec<(usize, T)> = tasks.into_iter().enumerate().collect();
        let results = self.pool.parallel_map(indexed, self.threads, |(i, t)| {
            if i > first_err.load(Ordering::Relaxed) {
                return None;
            }
            let r = f(t);
            if r.is_err() {
                first_err.fetch_min(i, Ordering::Relaxed);
            }
            Some(r)
        });
        let mut out = Vec::with_capacity(results.len());
        for r in results {
            match r {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => return Err(e),
                // Unreachable before the first error: a skip at index i
                // needs a recorded failure below i, and the scan returns
                // at that failure first.
                None => unreachable!("skipped task precedes the failure that cancelled it"),
            }
        }
        Ok(out)
    }

    /// The pipelined stage: `worker` runs on up to `threads` pool slots
    /// concurrently while `consumer` receives each result **in task
    /// order** on the calling thread (a reorder buffer sequences
    /// out-of-order completions). The consumer may therefore hold
    /// `&mut` state — ordered sinks, accumulators, ledgers — without any
    /// synchronization, and the overall effect is identical at any
    /// thread count.
    ///
    /// Backpressure: a worker does not *start* task `i` until
    /// `i < consumed + threads`, so at most `threads` results (plus the
    /// one each worker is computing) ever wait in the reorder buffer —
    /// memory stays O(threads), not O(tasks), even when the consumer is
    /// the slow side.
    ///
    /// A task or consumer error cancels the unclaimed queue; the stage
    /// returns the error seen at the smallest task index (results past
    /// it are discarded, their side effects never consumed). Called on
    /// a pool worker (or on a workerless pool) the stage runs inline —
    /// the sink must never park a budgeted thread.
    pub fn run_sequenced<T, R, F, C>(&self, tasks: Vec<T>, worker: F, consumer: C) -> Result<()>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> Result<R> + Sync,
        C: FnMut(usize, R) -> Result<()>,
    {
        let mut metrics = StageMetrics::default();
        self.run_sequenced_metered(tasks, worker, consumer, &mut metrics)
    }

    /// [`run_sequenced`] that also fills per-stage [`StageMetrics`]
    /// (surfaced by verbose slice reports).
    pub fn run_sequenced_metered<T, R, F, C>(
        &self,
        tasks: Vec<T>,
        worker: F,
        mut consumer: C,
        metrics: &mut StageMetrics,
    ) -> Result<()>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> Result<R> + Sync,
        C: FnMut(usize, R) -> Result<()>,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(());
        }
        let workers = self.threads.min(n);
        if workers == 1 || self.pool.spawned_threads() == 0 || hostpool::on_pool_worker() {
            for (i, t) in tasks.into_iter().enumerate() {
                let t0 = Instant::now();
                let r = worker(t)?;
                let nanos = t0.elapsed().as_nanos() as u64;
                task_hist().record(nanos);
                task_counter().inc();
                metrics.tasks += 1;
                metrics.busy_s += nanos as f64 / 1e9;
                metrics.peak_in_flight = metrics.peak_in_flight.max(1);
                consumer(i, r)?;
            }
            return Ok(());
        }

        let slots: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let cursor = AtomicUsize::new(0);
        let cancelled = AtomicBool::new(false);
        // Admission gate: consumed-watermark + condvar. Workers wait
        // until their task index is within `watermark + workers`.
        let gate: (Mutex<usize>, Condvar) = (Mutex::new(0), Condvar::new());
        let busy_nanos = AtomicU64::new(0);
        let in_flight = AtomicUsize::new(0);
        let peak_in_flight = AtomicUsize::new(0);

        enum Msg<R> {
            Done(Result<R>),
            Panicked(PanicPayload),
        }
        let (tx, rx) = mpsc::channel::<(usize, Msg<R>)>();
        // One sender shared by every claim loop (mpsc senders are not
        // Sync, so sends serialize through a mutex — cheap next to the
        // task bodies).
        let tx = Mutex::new(tx);

        let worker = &worker;
        let work = |_k: usize| {
            loop {
                if cancelled.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                // Backpressure: wait for admission. The task at the
                // watermark itself is always admitted, so the sink can
                // always make progress.
                {
                    let (lock, cv) = &gate;
                    let mut consumed = lock.lock().unwrap();
                    while i >= *consumed + workers && !cancelled.load(Ordering::Relaxed) {
                        consumed = cv.wait(consumed).unwrap();
                    }
                }
                if cancelled.load(Ordering::Relaxed) {
                    break;
                }
                let t = slots[i].lock().unwrap().take().expect("task claimed twice");
                let live = in_flight.fetch_add(1, Ordering::Relaxed) + 1;
                peak_in_flight.fetch_max(live, Ordering::Relaxed);
                let t0 = Instant::now();
                let outcome = catch_unwind(AssertUnwindSafe(|| worker(t)));
                let nanos = t0.elapsed().as_nanos() as u64;
                task_hist().record(nanos);
                busy_nanos.fetch_add(nanos, Ordering::Relaxed);
                in_flight.fetch_sub(1, Ordering::Relaxed);
                match outcome {
                    Ok(r) => {
                        if tx.lock().unwrap().send((i, Msg::Done(r))).is_err() {
                            break; // stage cancelled, receiver gone
                        }
                    }
                    Err(p) => {
                        // Fail the stage: wake gate-parked peers, hand
                        // the payload to the sink for re-raise.
                        cancelled.store(true, Ordering::Relaxed);
                        {
                            let _g = gate.0.lock().unwrap();
                            gate.1.notify_all();
                        }
                        let _ = tx.lock().unwrap().send((i, Msg::Panicked(p)));
                        break;
                    }
                }
            }
        };

        let handle = self.pool.scope_tickets(workers, workers, &work);

        // However the sink ends — completion, a consumer error, or a
        // consumer *panic* — the stage must be cancelled and the
        // admission-waiters woken, or the join below would hang on
        // parked claim loops. Declared after `handle` so it fires first
        // on unwind.
        struct CancelOnDrop<'a> {
            cancelled: &'a AtomicBool,
            gate: &'a (Mutex<usize>, Condvar),
        }
        impl Drop for CancelOnDrop<'_> {
            fn drop(&mut self) {
                self.cancelled.store(true, Ordering::Relaxed);
                let _g = self.gate.0.lock().unwrap();
                self.gate.1.notify_all();
            }
        }
        let cancel = CancelOnDrop {
            cancelled: &cancelled,
            gate: &gate,
        };

        // Sequenced sink: buffer out-of-order completions, deliver
        // strictly in task order, publish the watermark after each
        // delivery so waiting claim loops are admitted.
        let mut outcome: Result<()> = Ok(());
        let mut panicked: Option<PanicPayload> = None;
        let mut pending: BTreeMap<usize, Result<R>> = BTreeMap::new();
        let mut peak_pending = 0usize;
        let mut consumed_n = 0u64;
        let mut next = 0usize;
        'sink: while next < n {
            // Disconnect is impossible (the sender outlives the sink);
            // break defensively rather than unwrap.
            let Ok((i, msg)) = rx.recv() else { break 'sink };
            let r = match msg {
                Msg::Done(r) => r,
                Msg::Panicked(p) => {
                    panicked = Some(p);
                    break 'sink;
                }
            };
            pending.insert(i, r);
            peak_pending = peak_pending.max(pending.len());
            while let Some(r) = pending.remove(&next) {
                match r.and_then(|v| consumer(next, v)) {
                    Ok(()) => {
                        consumed_n += 1;
                        next += 1;
                        let (lock, cv) = &gate;
                        *lock.lock().unwrap() = next;
                        cv.notify_all();
                    }
                    Err(e) => {
                        outcome = Err(e);
                        break 'sink;
                    }
                }
            }
        }
        drop(cancel); // wake parked claim loops
        drop(rx); // in-flight sends fail fast
        handle.join(); // revoke queued tickets, wait for claimed ones
        task_counter().add(consumed_n);
        metrics.tasks += consumed_n;
        metrics.busy_s += busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        metrics.peak_in_flight = metrics
            .peak_in_flight
            .max(peak_in_flight.load(Ordering::Relaxed));
        metrics.peak_pending = metrics.peak_pending.max(peak_pending);
        if let Some(p) = panicked {
            resume_unwind(p);
        }
        outcome
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::PdfflowError;
    use std::panic::catch_unwind;

    #[test]
    fn run_preserves_task_order() {
        let exec = Executor::new(4);
        let out = exec.run((0..100).collect::<Vec<_>>(), |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn empty_task_set_is_a_noop() {
        let exec = Executor::new(8);
        let out: Vec<u32> = exec.run(Vec::new(), |x: u32| x);
        assert!(out.is_empty());
        assert!(exec.try_run(Vec::<u8>::new(), |x| Ok(x)).unwrap().is_empty());
        exec.run_sequenced(Vec::<u8>::new(), |x| Ok(x), |_, _| {
            panic!("consumer must not run")
        })
        .unwrap();
    }

    #[test]
    fn more_tasks_than_threads_runs_every_task_once() {
        let exec = Executor::new(3);
        let counter = AtomicU64::new(0);
        let out = exec.run((0..500).collect::<Vec<_>>(), |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
        assert_eq!(out.len(), 500);
    }

    #[test]
    fn panic_in_one_task_fails_the_stage() {
        for threads in [1usize, 4] {
            let exec = Executor::new(threads);
            let r = catch_unwind(AssertUnwindSafe(|| {
                exec.run((0..32).collect::<Vec<_>>(), |i| {
                    if i == 17 {
                        panic!("task 17 exploded");
                    }
                    i
                })
            }));
            assert!(r.is_err(), "threads={threads}: stage must fail");
        }
    }

    #[test]
    fn panic_fails_a_sequenced_stage_too() {
        let exec = Executor::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            exec.run_sequenced(
                (0..32).collect::<Vec<_>>(),
                |i| {
                    if i == 5 {
                        panic!("worker down");
                    }
                    Ok(i)
                },
                |_, _| Ok(()),
            )
        }));
        assert!(r.is_err());
    }

    #[test]
    fn panic_in_the_consumer_fails_the_stage_without_hanging() {
        // Claim loops parked at the admission gate must be woken when
        // the sink unwinds, or the stage join would deadlock.
        let exec = Executor::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            exec.run_sequenced(
                (0..64).collect::<Vec<_>>(),
                |i| Ok(i),
                |idx, _| {
                    if idx == 1 {
                        panic!("sink down");
                    }
                    Ok(())
                },
            )
        }));
        assert!(r.is_err());
    }

    #[test]
    fn backpressure_bounds_in_flight_results() {
        let threads = 3usize;
        let exec = Executor::new(threads);
        let started = AtomicUsize::new(0);
        exec.run_sequenced(
            (0..100).collect::<Vec<_>>(),
            |i| {
                started.fetch_add(1, Ordering::SeqCst);
                Ok(i)
            },
            |idx, _| {
                // Consumer is the slow side; the admission gate caps how
                // far workers run ahead of the consumed watermark.
                std::thread::sleep(std::time::Duration::from_micros(200));
                let s = started.load(Ordering::SeqCst);
                assert!(
                    s <= idx + threads,
                    "at idx {idx}: {s} tasks started, cap {}",
                    idx + threads
                );
                Ok(())
            },
        )
        .unwrap();
    }

    #[test]
    fn try_run_reports_smallest_failing_index() {
        for threads in [1usize, 2, 8] {
            let exec = Executor::new(threads);
            let err = exec
                .try_run((0..64).collect::<Vec<_>>(), |i| {
                    if i % 10 == 7 {
                        Err(PdfflowError::InvalidArg(format!("task {i}")))
                    } else {
                        Ok(i)
                    }
                })
                .unwrap_err();
            assert!(
                err.to_string().contains("task 7"),
                "threads={threads}: got {err}"
            );
        }
    }

    #[test]
    fn sequenced_consumer_sees_results_in_task_order() {
        for threads in [1usize, 2, 8] {
            let exec = Executor::new(threads);
            let mut seen = Vec::new();
            exec.run_sequenced(
                (0..50).collect::<Vec<_>>(),
                |i| {
                    // Uneven task durations scramble completion order.
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Ok(i * 2)
                },
                |idx, v| {
                    assert_eq!(v, idx * 2);
                    seen.push(idx);
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(seen, (0..50).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn sequenced_consumer_error_stops_consumption() {
        let exec = Executor::new(4);
        let mut consumed = 0usize;
        let err = exec
            .run_sequenced(
                (0..40).collect::<Vec<_>>(),
                |i| Ok(i),
                |idx, _| {
                    if idx == 3 {
                        return Err(PdfflowError::InvalidArg("sink full".into()));
                    }
                    consumed += 1;
                    Ok(())
                },
            )
            .unwrap_err();
        assert!(err.to_string().contains("sink full"));
        assert_eq!(consumed, 3, "exactly tasks 0..3 consumed");
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        let data: Vec<u64> = (0..256).collect();
        let exec = Executor::new(4);
        let out = exec.run((0..data.len()).collect::<Vec<_>>(), |i| data[i] + 1);
        assert_eq!(out.len(), 256);
        assert_eq!(out[255], 256);
    }

    #[test]
    fn stage_metrics_count_tasks_and_pending() {
        let exec = Executor::new(4);
        let mut m = StageMetrics::default();
        exec.run_sequenced_metered(
            (0..30).collect::<Vec<_>>(),
            |i| Ok(i),
            |_, _| Ok(()),
            &mut m,
        )
        .unwrap();
        assert_eq!(m.tasks, 30);
        assert!(m.peak_in_flight >= 1);
        assert!(m.busy_s >= 0.0);
    }

    #[test]
    fn sequenced_stage_runs_inline_on_a_pool_worker() {
        // A sequenced stage launched from inside a pool task must not
        // park the budgeted worker on a sink loop; it runs inline and
        // still honors ordering.
        let exec = Executor::new(4);
        let out = exec.run(vec![0u8; 3], |_| {
            let inner = Executor::new(4);
            let mut seen = Vec::new();
            inner
                .run_sequenced(
                    (0..10).collect::<Vec<_>>(),
                    |i| Ok(i),
                    |idx, v| {
                        assert_eq!(idx, v);
                        seen.push(v);
                        Ok(())
                    },
                )
                .unwrap();
            seen.len()
        });
        assert_eq!(out, vec![10, 10, 10]);
    }

    #[test]
    fn nested_try_run_inside_run_makes_progress() {
        // Help-first claim loops mean fallible nested stages complete
        // even when every pool worker is occupied by the outer stage.
        let exec = Executor::new(8);
        let out = exec
            .try_run((0..12u64).collect::<Vec<_>>(), |i| {
                let inner = Executor::new(4);
                let sums = inner.try_run((0..40u64).collect::<Vec<_>>(), |j| Ok(i * 1000 + j))?;
                Ok(sums.iter().sum::<u64>())
            })
            .unwrap();
        let expect: Vec<u64> = (0..12u64)
            .map(|i| (0..40u64).map(|j| i * 1000 + j).sum())
            .collect();
        assert_eq!(out, expect);
    }
}
