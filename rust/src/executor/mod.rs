//! Staged task executor: the driver-side scheduler that turns partition
//! and window work into parallel tasks (the Spark-scheduler analog of
//! the paper's §4.2 "parallel execution" principle).
//!
//! The executor runs a *stage*: a vector of independent tasks claimed
//! from a shared work queue by up to `threads` workers (work-stealing by
//! atomic cursor, like the partition task sets the Ripley's-K and
//! random-forest Spark systems schedule per stage). Two contracts make
//! the rest of the system simple:
//!
//! * **Deterministic task → result ordering.** Results are always
//!   delivered in task-index order, never completion order, so every
//!   caller observes the same output at any thread count.
//! * **Fail-fast stages.** A panicking task fails the whole stage (the
//!   panic propagates to the caller after all workers drain); a task
//!   returning `Err` cancels the remaining queue and the stage reports
//!   the error of the smallest failing task index.
//!
//! [`Executor::run_sequenced`] is the pipelined variant: workers compute
//! tasks concurrently while the calling thread consumes results through
//! a *sequenced sink* — a reorder buffer that invokes the consumer
//! strictly in task order. This is how the window pipeline overlaps
//! loading/fitting of window *i+1* with persisting window *i* while the
//! segment writer still sees windows in slice order.
//!
//! Workers are scoped threads spawned per stage: tasks may borrow from
//! the caller's stack (dataset readers, backends, caches), and an
//! `Executor` is just a thread-count policy — cheap to create, cheap to
//! share (`&Executor` is `Sync`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};

use crate::Result;

/// Default executor width: the `PDFFLOW_EXECUTOR_THREADS` environment
/// override when set to a positive integer, else all host cores.
pub fn default_threads() -> usize {
    std::env::var("PDFFLOW_EXECUTOR_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(crate::util::pool::default_workers)
}

/// A stage executor with a fixed worker-thread budget.
#[derive(Clone, Copy, Debug)]
pub struct Executor {
    threads: usize,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new(default_threads())
    }
}

impl Executor {
    /// An executor running at most `threads` concurrent tasks (clamped
    /// to at least 1).
    pub fn new(threads: usize) -> Executor {
        Executor {
            threads: threads.max(1),
        }
    }

    /// A single-threaded executor (tasks run inline, in order).
    pub fn sequential() -> Executor {
        Executor::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run one stage of infallible tasks; returns results in task order.
    /// A panic in any task propagates to the caller once every worker
    /// has drained (the stage fails as a unit). Scheduling delegates to
    /// the shared work-queue kernel in [`crate::util::pool`] — one
    /// claim-by-cursor implementation serves both the executor and the
    /// pool's direct users.
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Sync,
    {
        crate::util::pool::parallel_map(tasks, self.threads, f)
    }

    /// Run one stage of fallible tasks. On success returns all results
    /// in task order; on failure returns the error of the *smallest*
    /// failing task index (deterministic at any thread count) after
    /// cancelling the unclaimed remainder of the queue.
    pub fn try_run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> Result<R> + Sync,
    {
        let mut out = Vec::with_capacity(tasks.len());
        self.run_sequenced(tasks, f, |_, r| {
            out.push(r);
            Ok(())
        })?;
        Ok(out)
    }

    /// The pipelined stage: `worker` runs on up to `threads` tasks
    /// concurrently while `consumer` receives each result **in task
    /// order** on the calling thread (a reorder buffer sequences
    /// out-of-order completions). The consumer may therefore hold
    /// `&mut` state — ordered sinks, accumulators, ledgers — without any
    /// synchronization, and the overall effect is identical at any
    /// thread count.
    ///
    /// Backpressure: a worker does not *start* task `i` until
    /// `i < consumed + threads`, so at most `threads` results (plus the
    /// one each worker is computing) ever wait in the reorder buffer —
    /// memory stays O(threads), not O(tasks), even when the consumer is
    /// the slow side.
    ///
    /// A task or consumer error cancels the unclaimed queue; the stage
    /// returns the error seen at the smallest task index (results past
    /// it are discarded, their side effects never consumed).
    pub fn run_sequenced<T, R, F, C>(
        &self,
        tasks: Vec<T>,
        worker: F,
        mut consumer: C,
    ) -> Result<()>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> Result<R> + Sync,
        C: FnMut(usize, R) -> Result<()>,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(());
        }
        let workers = self.threads.min(n);
        if workers == 1 {
            for (i, t) in tasks.into_iter().enumerate() {
                consumer(i, worker(t)?)?;
            }
            return Ok(());
        }
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let cursor = AtomicUsize::new(0);
        let cancelled = AtomicBool::new(false);
        // Admission gate: consumed-watermark + condvar. Workers wait
        // until their task index is within `watermark + workers`.
        let gate: (Mutex<usize>, Condvar) = (Mutex::new(0), Condvar::new());
        let (tx, rx) = mpsc::channel::<(usize, Result<R>)>();
        let mut outcome: Result<()> = Ok(());

        /// Unwinding out of a worker (or out of the sink) must wake
        /// gate-waiting peers and cancel the stage, or they would wait
        /// for a watermark that will never advance and `scope`'s join
        /// would hang forever.
        struct PanicRelease<'a> {
            cancelled: &'a AtomicBool,
            gate: &'a (Mutex<usize>, Condvar),
            armed: bool,
        }
        impl Drop for PanicRelease<'_> {
            fn drop(&mut self) {
                if self.armed {
                    self.cancelled.store(true, Ordering::Relaxed);
                    let _unused = self.gate.0.lock().unwrap();
                    self.gate.1.notify_all();
                }
            }
        }

        std::thread::scope(|scope| {
            let slots = &slots;
            let cursor = &cursor;
            let cancelled = &cancelled;
            let gate = &gate;
            let worker = &worker;
            for _ in 0..workers {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    if cancelled.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // Backpressure: wait for admission. The task at the
                    // watermark itself is always admitted (workers > 0),
                    // so the sink can always make progress.
                    {
                        let (lock, cv) = gate;
                        let mut consumed = lock.lock().unwrap();
                        while i >= *consumed + workers && !cancelled.load(Ordering::Relaxed) {
                            consumed = cv.wait(consumed).unwrap();
                        }
                    }
                    if cancelled.load(Ordering::Relaxed) {
                        break;
                    }
                    let t = slots[i].lock().unwrap().take().expect("task claimed twice");
                    let mut release = PanicRelease {
                        cancelled,
                        gate,
                        armed: true,
                    };
                    let r = worker(t);
                    release.armed = false;
                    if tx.send((i, r)).is_err() {
                        break; // stage cancelled, receiver gone
                    }
                });
            }
            drop(tx);

            // However the sink ends — completion, a consumer error, or
            // a consumer *panic* — the stage must be cancelled and the
            // admission-waiters woken, or scope's join would hang on
            // parked workers. The armed guard covers all three paths.
            let _sink_release = PanicRelease {
                cancelled,
                gate,
                armed: true,
            };

            // Sequenced sink: buffer out-of-order completions, deliver
            // strictly in task order, publish the watermark after each
            // delivery so waiting workers are admitted.
            let mut pending: BTreeMap<usize, Result<R>> = BTreeMap::new();
            let mut next = 0usize;
            'sink: while next < n {
                // Channel disconnect before all results arrived means a
                // worker panicked; fall through and let scope propagate.
                let Ok((i, r)) = rx.recv() else { break 'sink };
                pending.insert(i, r);
                while let Some(r) = pending.remove(&next) {
                    let step = r.and_then(|v| consumer(next, v));
                    match step {
                        Ok(()) => {
                            next += 1;
                            let (lock, cv) = &gate;
                            *lock.lock().unwrap() = next;
                            cv.notify_all();
                        }
                        Err(e) => {
                            outcome = Err(e);
                            break 'sink;
                        }
                    }
                }
            }
            // Drop the receiver so in-flight sends fail fast; the sink
            // guard then cancels + notifies, and scope joins the workers
            // (re-raising any panic).
            drop(rx);
        });
        outcome
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::PdfflowError;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_preserves_task_order() {
        let exec = Executor::new(4);
        let out = exec.run((0..100).collect::<Vec<_>>(), |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn empty_task_set_is_a_noop() {
        let exec = Executor::new(8);
        let out: Vec<u32> = exec.run(Vec::new(), |x: u32| x);
        assert!(out.is_empty());
        assert!(exec.try_run(Vec::<u8>::new(), |x| Ok(x)).unwrap().is_empty());
        exec.run_sequenced(Vec::<u8>::new(), |x| Ok(x), |_, _| {
            panic!("consumer must not run")
        })
        .unwrap();
    }

    #[test]
    fn more_tasks_than_threads_runs_every_task_once() {
        let exec = Executor::new(3);
        let counter = AtomicU64::new(0);
        let out = exec.run((0..500).collect::<Vec<_>>(), |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 500);
        assert_eq!(out.len(), 500);
    }

    #[test]
    fn panic_in_one_task_fails_the_stage() {
        for threads in [1usize, 4] {
            let exec = Executor::new(threads);
            let r = catch_unwind(AssertUnwindSafe(|| {
                exec.run((0..32).collect::<Vec<_>>(), |i| {
                    if i == 17 {
                        panic!("task 17 exploded");
                    }
                    i
                })
            }));
            assert!(r.is_err(), "threads={threads}: stage must fail");
        }
    }

    #[test]
    fn panic_fails_a_sequenced_stage_too() {
        let exec = Executor::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            exec.run_sequenced(
                (0..32).collect::<Vec<_>>(),
                |i| {
                    if i == 5 {
                        panic!("worker down");
                    }
                    Ok(i)
                },
                |_, _| Ok(()),
            )
        }));
        assert!(r.is_err());
    }

    #[test]
    fn panic_in_the_consumer_fails_the_stage_without_hanging() {
        // Workers parked at the admission gate must be woken when the
        // sink unwinds, or scope's join would deadlock.
        let exec = Executor::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            exec.run_sequenced(
                (0..64).collect::<Vec<_>>(),
                |i| Ok(i),
                |idx, _| {
                    if idx == 1 {
                        panic!("sink down");
                    }
                    Ok(())
                },
            )
        }));
        assert!(r.is_err());
    }

    #[test]
    fn backpressure_bounds_in_flight_results() {
        use std::sync::atomic::AtomicUsize;
        let threads = 3usize;
        let exec = Executor::new(threads);
        let started = AtomicUsize::new(0);
        exec.run_sequenced(
            (0..100).collect::<Vec<_>>(),
            |i| {
                started.fetch_add(1, Ordering::SeqCst);
                Ok(i)
            },
            |idx, _| {
                // Consumer is the slow side; the admission gate caps how
                // far workers run ahead of the consumed watermark.
                std::thread::sleep(std::time::Duration::from_micros(200));
                let s = started.load(Ordering::SeqCst);
                assert!(
                    s <= idx + threads,
                    "at idx {idx}: {s} tasks started, cap {}",
                    idx + threads
                );
                Ok(())
            },
        )
        .unwrap();
    }

    #[test]
    fn try_run_reports_smallest_failing_index() {
        for threads in [1usize, 2, 8] {
            let exec = Executor::new(threads);
            let err = exec
                .try_run((0..64).collect::<Vec<_>>(), |i| {
                    if i % 10 == 7 {
                        Err(PdfflowError::InvalidArg(format!("task {i}")))
                    } else {
                        Ok(i)
                    }
                })
                .unwrap_err();
            assert!(
                err.to_string().contains("task 7"),
                "threads={threads}: got {err}"
            );
        }
    }

    #[test]
    fn sequenced_consumer_sees_results_in_task_order() {
        for threads in [1usize, 2, 8] {
            let exec = Executor::new(threads);
            let mut seen = Vec::new();
            exec.run_sequenced(
                (0..50).collect::<Vec<_>>(),
                |i| {
                    // Uneven task durations scramble completion order.
                    if i % 7 == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Ok(i * 2)
                },
                |idx, v| {
                    assert_eq!(v, idx * 2);
                    seen.push(idx);
                    Ok(())
                },
            )
            .unwrap();
            assert_eq!(seen, (0..50).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn sequenced_consumer_error_stops_consumption() {
        let exec = Executor::new(4);
        let mut consumed = 0usize;
        let err = exec
            .run_sequenced(
                (0..40).collect::<Vec<_>>(),
                |i| Ok(i),
                |idx, _| {
                    if idx == 3 {
                        return Err(PdfflowError::InvalidArg("sink full".into()));
                    }
                    consumed += 1;
                    Ok(())
                },
            )
            .unwrap_err();
        assert!(err.to_string().contains("sink full"));
        assert_eq!(consumed, 3, "exactly tasks 0..3 consumed");
    }

    #[test]
    fn tasks_may_borrow_caller_state() {
        let data: Vec<u64> = (0..256).collect();
        let exec = Executor::new(4);
        let out = exec.run((0..data.len()).collect::<Vec<_>>(), |i| data[i] + 1);
        assert_eq!(out.len(), 256);
        assert_eq!(out[255], 256);
    }
}
