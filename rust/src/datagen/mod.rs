//! Synthetic seismic dataset generator — the HPC4e-benchmark / UQLab
//! analog (DESIGN.md §3, substitution row 2).
//!
//! The paper's data: a 16-layer seismic model; each layer's wave velocity
//! Vp is uncertain with a distribution family cycling through
//! {normal, log-normal, exponential, uniform}; each Monte-Carlo simulation
//! draws the 16 inputs and produces one spatial dataset file; a point's
//! observation vector is its value across the K simulation files.
//!
//! Our generator preserves the properties the paper's methods exploit:
//!
//! * **file-per-simulation layout** with z-major point order (NFS gather
//!   pattern of Algorithm 2);
//! * **grouping ratio** — points inside a layer share observation vectors
//!   when they have the same quantized gain level, so a tunable fraction
//!   of points is redundant (Grouping's win);
//! * **learnable (mean, std) → type correlation** — pure points keep their
//!   layer's family under multiplicative gain, and family parameters make
//!   layers separable in (mean, std) space (ML's win);
//! * **type diversity inside a slice** — interface points blend adjacent
//!   layers (the paper's "non-linear relationship" motivating 10-types).

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};

use crate::cube::CubeDims;
use crate::stats::DistType;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::{PdfflowError, Result};

/// How a point derives its value from the layer input draws.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PointKind {
    /// `v = gain * u_layer` — keeps the layer's distribution family.
    Pure,
    /// `v = gain * (alpha*u_layer + (1-alpha)*u_next)` — mixes adjacent
    /// layers into an out-of-family distribution.
    Blend,
    /// Pure plus per-(point, simulation) jitter — a unique observation
    /// vector that defeats grouping.
    Unique,
}

/// One of the model's value layers.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub family: DistType,
    /// Base wave velocity (location scale of the layer's distribution).
    pub vp: f64,
    /// Relative uncertainty (spread / vp).
    pub spread: f64,
}

impl LayerSpec {
    /// Draw one Monte-Carlo input value for this layer.
    pub fn draw(&self, rng: &mut Rng) -> f64 {
        let s = self.vp * self.spread;
        match self.family {
            DistType::Normal => rng.normal(self.vp, s),
            DistType::Lognormal => {
                // Parametrize so that E[v] ~ vp and relative sd ~ spread.
                let sigma2 = (1.0 + self.spread * self.spread).ln();
                let mu = self.vp.ln() - 0.5 * sigma2;
                rng.lognormal(mu, sigma2.sqrt())
            }
            DistType::Exponential => rng.exponential(1.0 / self.vp),
            DistType::Uniform => {
                let half = s * 3f64.sqrt(); // matches std = s
                rng.uniform(self.vp - half, self.vp + half)
            }
            other => panic!("layer family {other:?} not an input family"),
        }
    }
}

/// Full dataset specification (persisted to `dataset.json`).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub dims: CubeDims,
    pub n_sims: usize,
    pub n_layers: usize,
    /// Gain quantization levels per layer: points sharing a level share
    /// their observation vector (drives the grouping ratio).
    pub group_levels: usize,
    /// Fraction of interface (blend) points.
    pub blend_fraction: f64,
    /// Fraction of unique-noise points.
    pub unique_fraction: f64,
    /// Relative amplitude of the per-(point, sim) jitter on Unique points.
    pub unique_noise: f64,
    pub seed: u64,
}

impl DatasetSpec {
    /// Set1-analog defaults at laptop scale (see DESIGN.md §3).
    /// `group_levels`/`unique_fraction` are calibrated so a full slice
    /// has ~25-30% distinct (mean, std) groups, matching the redundancy
    /// the paper's Grouping numbers imply (69-92% time reduction).
    pub fn set1_analog() -> Self {
        DatasetSpec {
            dims: CubeDims::new(251, 96, 96),
            n_sims: 1000,
            n_layers: 16,
            group_levels: 32,
            blend_fraction: 0.15,
            unique_fraction: 0.15,
            unique_noise: 0.02,
            seed: 20180515,
        }
    }

    /// Tiny dataset for unit/integration tests (matches 64x100 artifacts).
    pub fn tiny() -> Self {
        DatasetSpec {
            dims: CubeDims::new(16, 12, 8),
            n_sims: 100,
            n_layers: 16,
            group_levels: 16,
            blend_fraction: 0.15,
            unique_fraction: 0.25,
            unique_noise: 0.02,
            seed: 7,
        }
    }

    /// The paper's 16 layers: families cycle Normal, Lognormal,
    /// Exponential, Uniform ("the distribution type for every four layers").
    /// Layer 0 is topography (metadata only); layers 1..16 carry values.
    pub fn layers(&self) -> Vec<LayerSpec> {
        let families = [
            DistType::Normal,
            DistType::Lognormal,
            DistType::Exponential,
            DistType::Uniform,
        ];
        (0..self.n_layers)
            .map(|i| LayerSpec {
                family: families[i % 4],
                // Vp grows with depth (roughly 1500..5500 m/s) so layers
                // are separable in (mean, std) space.
                vp: 1500.0 + 270.0 * i as f64,
                spread: 0.04 + 0.015 * (i % 5) as f64,
            })
            .collect()
    }

    /// Number of *value* layers (all but the topography layer).
    pub fn n_value_layers(&self) -> usize {
        self.n_layers - 1
    }

    /// Which value layer a slice belongs to.
    pub fn layer_of_slice(&self, z: usize) -> usize {
        let nv = self.n_value_layers();
        (z * nv / self.dims.nz).min(nv - 1)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nx", Json::Num(self.dims.nx as f64)),
            ("ny", Json::Num(self.dims.ny as f64)),
            ("nz", Json::Num(self.dims.nz as f64)),
            ("n_sims", Json::Num(self.n_sims as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("group_levels", Json::Num(self.group_levels as f64)),
            ("blend_fraction", Json::Num(self.blend_fraction)),
            ("unique_fraction", Json::Num(self.unique_fraction)),
            ("unique_noise", Json::Num(self.unique_noise)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let get = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| PdfflowError::Format(format!("dataset.json missing {k}")))
        };
        Ok(DatasetSpec {
            dims: CubeDims::new(get("nx")? as usize, get("ny")? as usize, get("nz")? as usize),
            n_sims: get("n_sims")? as usize,
            n_layers: get("n_layers")? as usize,
            group_levels: get("group_levels")? as usize,
            blend_fraction: get("blend_fraction")?,
            unique_fraction: get("unique_fraction")?,
            unique_noise: get("unique_noise")?,
            seed: get("seed")? as u64,
        })
    }
}

/// Deterministic per-point attributes (kind, gain level, blend alpha),
/// derived by hashing the point's (x, y) and its layer — identical across
/// simulations, which is what makes observation vectors group.
#[derive(Clone, Copy, Debug)]
pub struct PointProfile {
    pub kind: PointKind,
    pub layer: usize,
    pub gain: f64,
    pub alpha: f64,
}

fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl DatasetSpec {
    /// Per-point profile. Depends only on (x, y, layer, seed): every slice
    /// of a layer has the same planform, like a real stratum.
    pub fn point_profile(&self, x: usize, y: usize, z: usize) -> PointProfile {
        let layer = self.layer_of_slice(z);
        let h = mix64(
            (x as u64)
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add((y as u64).wrapping_mul(0xD1B54A32D192ED03))
                .wrapping_add((layer as u64) << 32)
                .wrapping_add(self.seed),
        );
        let u_kind = (h >> 11) as f64 / (1u64 << 53) as f64;
        let kind = if u_kind < self.blend_fraction {
            PointKind::Blend
        } else if u_kind < self.blend_fraction + self.unique_fraction {
            PointKind::Unique
        } else {
            PointKind::Pure
        };
        let level = (mix64(h ^ 0xA5A5) % self.group_levels as u64) as f64;
        let gain = 0.85 + 0.30 * level / (self.group_levels.max(2) - 1) as f64;
        // Blend coefficient quantized to 3 levels so blends also group.
        let alpha = [0.35, 0.5, 0.65][(mix64(h ^ 0x5A5A) % 3) as usize];
        PointProfile {
            kind,
            layer,
            gain,
            alpha,
        }
    }

    /// Ground-truth input family of a point (meaningful for Pure/Unique
    /// points; Blend points are out-of-family by construction).
    pub fn true_family(&self, x: usize, y: usize, z: usize) -> Option<DistType> {
        let p = self.point_profile(x, y, z);
        match p.kind {
            PointKind::Blend => None,
            _ => Some(self.layers()[p.layer + 1].family),
        }
    }
}

/// File format: 32-byte header then nx*ny*nz little-endian f32 values in
/// z-major (slice, line, point) order.
pub const MAGIC: &[u8; 4] = b"PDFC";
pub const HEADER_LEN: u64 = 32;
pub const VERSION: u32 = 1;

fn write_header(w: &mut impl std::io::Write, spec: &DatasetSpec, sim: u32) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(spec.dims.nx as u32).to_le_bytes())?;
    w.write_all(&(spec.dims.ny as u32).to_le_bytes())?;
    w.write_all(&(spec.dims.nz as u32).to_le_bytes())?;
    w.write_all(&sim.to_le_bytes())?;
    w.write_all(&(spec.n_sims as u32).to_le_bytes())?;
    w.write_all(&[0u8; 4])?; // padding to 32 bytes
    Ok(())
}

/// A generated (or re-opened) dataset on disk.
#[derive(Clone, Debug)]
pub struct SyntheticDataset {
    pub spec: DatasetSpec,
    pub dir: PathBuf,
    pub files: Vec<PathBuf>,
}

impl SyntheticDataset {
    pub fn file_name(sim: usize) -> String {
        format!("sim_{sim:05}.pdfc")
    }

    /// Generate all simulation files under `dir` (skips generation if a
    /// matching dataset.json already exists — `make artifacts` semantics).
    pub fn generate(spec: &DatasetSpec, dir: impl AsRef<Path>) -> Result<SyntheticDataset> {
        let dir = dir.as_ref().to_path_buf();
        if let Ok(existing) = Self::open(&dir) {
            if existing.spec.to_json() == spec.to_json() {
                return Ok(existing);
            }
        }
        std::fs::create_dir_all(&dir)?;
        let layers = spec.layers();
        let master = Rng::new(spec.seed);
        let dims = spec.dims;
        // Precompute per-point profiles for one slice planform per layer:
        // profiles depend on (x, y, layer) only.
        let nv = spec.n_value_layers();
        let mut profiles: Vec<Option<Vec<PointProfile>>> = vec![None; nv];
        for z in 0..dims.nz {
            let layer = spec.layer_of_slice(z);
            if profiles[layer].is_none() {
                let mut v = Vec::with_capacity(dims.slice_points());
                for y in 0..dims.ny {
                    for x in 0..dims.nx {
                        v.push(spec.point_profile(x, y, z));
                    }
                }
                profiles[layer] = Some(v);
            }
        }

        let mut files = Vec::with_capacity(spec.n_sims);
        for sim in 0..spec.n_sims {
            let path = dir.join(Self::file_name(sim));
            let mut w = BufWriter::with_capacity(1 << 20, File::create(&path)?);
            write_header(&mut w, spec, sim as u32)?;
            // Monte-Carlo input draws for this simulation: one per value
            // layer (UQLab analog) + the next-layer draw used by blends.
            let mut sim_rng = master.fork(sim as u64);
            let draws: Vec<f64> = (0..nv).map(|l| layers[l + 1].draw(&mut sim_rng)).collect();
            let mut jitter_rng = master.fork(0x4000_0000 + sim as u64);
            let mut buf: Vec<u8> = Vec::with_capacity(dims.slice_points() * 4);
            for z in 0..dims.nz {
                let layer = spec.layer_of_slice(z);
                let next = (layer + 1).min(nv - 1);
                let (u, u_next) = (draws[layer], draws[next]);
                buf.clear();
                for p in profiles[layer].as_ref().expect("layer profile built") {
                    let base = match p.kind {
                        PointKind::Pure => p.gain * u,
                        PointKind::Blend => p.gain * (p.alpha * u + (1.0 - p.alpha) * u_next),
                        PointKind::Unique => {
                            p.gain * u * (1.0 + spec.unique_noise * jitter_rng.std_normal())
                        }
                    };
                    buf.extend_from_slice(&(base as f32).to_le_bytes());
                }
                w.write_all(&buf)?;
            }
            w.flush()?;
            files.push(path);
        }
        let ds = SyntheticDataset {
            spec: spec.clone(),
            dir: dir.clone(),
            files,
        };
        std::fs::write(dir.join("dataset.json"), ds.spec.to_json().to_string())?;
        Ok(ds)
    }

    /// Open an existing dataset directory.
    pub fn open(dir: impl AsRef<Path>) -> Result<SyntheticDataset> {
        let dir = dir.as_ref().to_path_buf();
        let meta = std::fs::read_to_string(dir.join("dataset.json"))?;
        let spec = DatasetSpec::from_json(
            &Json::parse(&meta).map_err(PdfflowError::Format)?,
        )?;
        let files: Vec<PathBuf> = (0..spec.n_sims)
            .map(|k| dir.join(Self::file_name(k)))
            .collect();
        for f in &files {
            if !f.exists() {
                return Err(PdfflowError::Format(format!("missing {}", f.display())));
            }
        }
        Ok(SyntheticDataset { spec, dir, files })
    }

    /// Total size on disk (all simulation files).
    pub fn total_bytes(&self) -> u64 {
        self.spec.n_sims as u64 * (HEADER_LEN + self.spec.dims.n_points() as u64 * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pdfflow-datagen-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn generate_and_reopen() {
        let spec = DatasetSpec::tiny();
        let dir = tmpdir("gen");
        let ds = SyntheticDataset::generate(&spec, &dir).unwrap();
        assert_eq!(ds.files.len(), spec.n_sims);
        let size = std::fs::metadata(&ds.files[0]).unwrap().len();
        assert_eq!(size, HEADER_LEN + spec.dims.n_points() as u64 * 4);
        let re = SyntheticDataset::open(&dir).unwrap();
        assert_eq!(re.spec.dims, spec.dims);
        assert_eq!(re.files.len(), spec.n_sims);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn generation_is_deterministic() {
        let spec = DatasetSpec::tiny();
        let (d1, d2) = (tmpdir("det1"), tmpdir("det2"));
        SyntheticDataset::generate(&spec, &d1).unwrap();
        SyntheticDataset::generate(&spec, &d2).unwrap();
        let a = std::fs::read(d1.join(SyntheticDataset::file_name(3))).unwrap();
        let b = std::fs::read(d2.join(SyntheticDataset::file_name(3))).unwrap();
        assert_eq!(a, b);
        std::fs::remove_dir_all(&d1).unwrap();
        std::fs::remove_dir_all(&d2).unwrap();
    }

    #[test]
    fn regenerate_is_noop_when_spec_matches() {
        let spec = DatasetSpec::tiny();
        let dir = tmpdir("noop");
        SyntheticDataset::generate(&spec, &dir).unwrap();
        let mtime = std::fs::metadata(dir.join(SyntheticDataset::file_name(0)))
            .unwrap()
            .modified()
            .unwrap();
        SyntheticDataset::generate(&spec, &dir).unwrap();
        let mtime2 = std::fs::metadata(dir.join(SyntheticDataset::file_name(0)))
            .unwrap()
            .modified()
            .unwrap();
        assert_eq!(mtime, mtime2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn layer_mapping_covers_all_layers() {
        let spec = DatasetSpec::tiny();
        let mut seen = std::collections::BTreeSet::new();
        for z in 0..spec.dims.nz {
            let l = spec.layer_of_slice(z);
            assert!(l < spec.n_value_layers());
            seen.insert(l);
        }
        assert!(seen.len() >= spec.dims.nz.min(spec.n_value_layers()) / 2);
        assert_eq!(*seen.iter().next().unwrap(), 0);
    }

    #[test]
    fn profiles_constant_across_sims_vary_across_points() {
        let spec = DatasetSpec::tiny();
        let p1 = spec.point_profile(3, 5, 2);
        let p2 = spec.point_profile(3, 5, 2);
        assert_eq!(p1.gain, p2.gain);
        let kinds: std::collections::BTreeSet<_> = (0..spec.dims.ny)
            .flat_map(|y| (0..spec.dims.nx).map(move |x| (x, y)))
            .map(|(x, y)| format!("{:?}", spec.point_profile(x, y, 0).kind))
            .collect();
        assert!(kinds.len() >= 2, "expected kind diversity, got {kinds:?}");
    }

    #[test]
    fn kind_fractions_roughly_match_spec() {
        let spec = DatasetSpec::set1_analog();
        let n = spec.dims.slice_points() as f64;
        let mut blend = 0.0;
        let mut unique = 0.0;
        for y in 0..spec.dims.ny {
            for x in 0..spec.dims.nx {
                match spec.point_profile(x, y, 0).kind {
                    PointKind::Blend => blend += 1.0,
                    PointKind::Unique => unique += 1.0,
                    PointKind::Pure => {}
                }
            }
        }
        assert!((blend / n - spec.blend_fraction).abs() < 0.03);
        assert!((unique / n - spec.unique_fraction).abs() < 0.03);
    }

    #[test]
    fn layer_draw_families_have_expected_support() {
        let spec = DatasetSpec::tiny();
        let layers = spec.layers();
        let mut rng = Rng::new(1);
        for l in &layers {
            for _ in 0..200 {
                let v = l.draw(&mut rng);
                match l.family {
                    DistType::Exponential | DistType::Lognormal => assert!(v >= 0.0),
                    _ => {}
                }
                assert!(v.is_finite());
            }
        }
    }

    #[test]
    fn true_family_follows_layer_cycle() {
        let spec = DatasetSpec::tiny();
        // Find a pure point on slice 0 (layer 0 -> layers()[1] family).
        for y in 0..spec.dims.ny {
            for x in 0..spec.dims.nx {
                if spec.point_profile(x, y, 0).kind == PointKind::Pure {
                    assert_eq!(
                        spec.true_family(x, y, 0),
                        Some(spec.layers()[1].family)
                    );
                    return;
                }
            }
        }
        panic!("no pure point found");
    }
}
