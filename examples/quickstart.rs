//! Quickstart: generate a tiny synthetic seismic dataset, compute the
//! PDFs of one slice with two methods, and print the paper's headline
//! comparison. Runs in well under a minute.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use pdfflow::prelude::*;

fn main() -> Result<()> {
    // 1. A small experiment: 16x12x8 cube, 100 Monte-Carlo simulations.
    let cfg = ExperimentConfig::small();

    // 2. Generate (or reuse) the dataset — the HPC4e-benchmark analog.
    let data = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    println!(
        "dataset: {} simulations x {} points ({} per line)",
        data.spec.n_sims,
        data.spec.dims.n_points(),
        data.spec.dims.nx
    );

    // 3. Build the compute backend: native by default (no artifacts
    //    needed); set PDFFLOW_BACKEND=xla on an xla-feature build to use
    //    the AOT-compiled PJRT engine instead.
    let backend = cfg.make_backend()?;
    println!("compute backend: {}", backend.name());

    // 4. Run Baseline, then Grouping+ML, on the configured slice.
    let mut pipeline = Pipeline::new(
        &data,
        backend.as_ref(),
        SimCluster::new(cfg.cluster.clone()),
        cfg.pipeline.clone(),
    );
    let baseline = pipeline.run_slice(Method::Baseline, cfg.slice, TypeSet::Four)?;
    println!("baseline     {}", baseline.row());

    pipeline.ensure_tree(cfg.train_slice, TypeSet::Four, 1000)?;
    let combined = pipeline.run_slice(Method::GroupingMl, cfg.slice, TypeSet::Four)?;
    println!("grouping+ml  {}", combined.row());

    println!(
        "\ngrouping+ml is {:.1}x faster than baseline (simulated cluster time), \
         error {:.4} vs {:.4}",
        baseline.fit_sim_s / combined.fit_sim_s.max(1e-12),
        combined.avg_error,
        baseline.avg_error
    );
    Ok(())
}
