//! Scalability study (paper §6.2.2, Figs. 12-14): how data loading and
//! each PDF-computation method scale from 10 to 60 simulated Grid5000
//! nodes, including the ML vs Grouping+ML crossover.
//!
//! ```text
//! cargo run --release --example scalability_study
//! ```

use anyhow::Result;
use pdfflow::coordinator::loader::load_window;
use pdfflow::cube::CubeDims;
use pdfflow::prelude::*;
use pdfflow::storage::{DatasetReader, WindowCache};
use pdfflow::util::timing::fmt_secs;

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::set1();
    cfg.dataset.dims = CubeDims::new(256, 64, 64);
    cfg.dataset.n_sims = 100;
    cfg.pipeline.window_lines = 16;
    cfg.slice = cfg.dataset.dims.nz * 201 / 501;
    cfg.data_dir = "data/example-seismic".into(); // shared with seismic_slice

    let data = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;

    // Fig 12 analog: loading time vs nodes (cold cache each time).
    println!("{:<8} {:>14}", "nodes", "loading(sim)");
    for nodes in [10, 20, 30, 40, 50, 60] {
        let reader = DatasetReader::new(&data);
        let cache = WindowCache::new(0);
        let cluster = SimCluster::new(ClusterSpec::g5k(nodes));
        for w in data.spec.dims.windows(cfg.slice, cfg.pipeline.window_lines) {
            load_window(&reader, &cache, backend.as_ref(), &cluster, w)?;
        }
        println!("{:<8} {:>14}", nodes, fmt_secs(cluster.total()));
    }

    // Fig 13/14 analog: PDF computation vs nodes per method.
    let methods = [
        Method::Baseline,
        Method::Grouping,
        Method::Ml,
        Method::GroupingMl,
    ];
    print!("\n{:<8}", "nodes");
    for m in &methods {
        print!(" {:>14}", m.name());
    }
    println!("   (fit sim, 10-types)");
    for nodes in [10, 20, 30, 40, 50, 60] {
        let mut pipeline = Pipeline::new(
            &data,
            backend.as_ref(),
            SimCluster::new(ClusterSpec::g5k(nodes)),
            cfg.pipeline.clone(),
        );
        pipeline.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;
        print!("{:<8}", nodes);
        let mut times = Vec::new();
        for m in &methods {
            let r = pipeline.run_slice(*m, cfg.slice, TypeSet::Ten)?;
            times.push(r.fit_sim_s);
            print!(" {:>14}", fmt_secs(r.fit_sim_s));
        }
        let ml = times[2];
        let gml = times[3];
        println!(
            "   winner: {}",
            if ml < gml { "ml" } else { "grouping+ml" }
        );
    }
    println!("\npaper Fig. 14: Grouping+ML wins on small clusters; ML overtakes past ~10-20 nodes.");
    Ok(())
}
