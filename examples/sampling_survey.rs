//! Sampling survey (paper §6.2.3, Figs. 15-17): random vs k-means
//! double-sampling — loading scales with the rate, PDF "computation" is
//! a flat tree-prediction pass, and k-means pays a full-slice load for a
//! better type-percentage estimate at low rates.
//!
//! ```text
//! cargo run --release --example sampling_survey
//! ```

use anyhow::Result;
use pdfflow::coordinator::sampling::{full_slice_features, run_sampling};
use pdfflow::coordinator::Sampler;
use pdfflow::cube::CubeDims;
use pdfflow::prelude::*;
use pdfflow::storage::{DatasetReader, WindowCache};
use pdfflow::util::timing::fmt_secs;

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::set1();
    cfg.dataset.dims = CubeDims::new(256, 64, 64);
    cfg.dataset.n_sims = 100;
    cfg.pipeline.window_lines = 16;
    cfg.slice = cfg.dataset.dims.nz * 201 / 501;
    cfg.data_dir = "data/example-seismic".into();

    let data = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    let backend = cfg.make_backend()?;
    let mut pipeline = Pipeline::new(
        &data,
        backend.as_ref(),
        SimCluster::new(cfg.cluster.clone()),
        cfg.pipeline.clone(),
    );
    pipeline.ensure_tree(cfg.train_slice, TypeSet::Four, 25_000)?;
    let tree = pipeline.tree.clone().unwrap();

    let reader = DatasetReader::new(&data);
    let cache = WindowCache::new(512 << 20);
    let cluster = SimCluster::new(cfg.cluster.clone());
    let full = full_slice_features(&reader, &cache, backend.as_ref(), &cluster, &tree, cfg.slice)?;

    for sampler in [Sampler::Random, Sampler::KMeans] {
        println!(
            "\n{:<8} {:>9} {:>12} {:>13} {:>10}",
            sampler.name(),
            "sampled",
            "load(real)",
            "compute(real)",
            "distance"
        );
        let rates: &[f64] = match sampler {
            Sampler::Random => &[0.001, 0.01, 0.1, 0.2, 0.5, 1.0],
            Sampler::KMeans => &[0.2, 0.4, 0.6, 0.8, 1.0],
        };
        for &rate in rates {
            let rep = run_sampling(
                &reader, &cache, backend.as_ref(), &cluster, &tree, cfg.slice, rate, sampler, 42,
            )?;
            println!(
                "{:<8} {:>9} {:>12} {:>13} {:>10.4}",
                rate,
                rep.n_sampled,
                fmt_secs(rep.load_real_s),
                fmt_secs(rep.compute_real_s),
                rep.features.type_distance(&full)
            );
        }
    }
    println!("\npaper: random sampling loads linearly in rate with ~flat compute;");
    println!("k-means needs the whole slice loaded, so it is only competitive when");
    println!("the rate is low and the distance matters (Fig. 17).");
    Ok(())
}
