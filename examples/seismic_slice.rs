//! END-TO-END driver (DESIGN.md §Experiment-Index "headline"): the full
//! system on a real small workload.
//!
//! Reproduces the paper's §6.2 experiment at 1/100 scale: generate the
//! Set1-analog seismic dataset (100 simulations of a 256x64x64 cube),
//! train the decision tree on previously generated output, run all six
//! methods x {4,10}-types over the Slice-201 analog, and report the
//! paper's headline: how many times faster than Baseline the best method
//! is, at what error cost. Finishes with the Sampling feature survey.
//!
//! ```text
//! cargo run --release --example seismic_slice
//! ```

use anyhow::Result;
use pdfflow::coordinator::sampling::run_sampling;
use pdfflow::coordinator::Sampler;
use pdfflow::cube::CubeDims;
use pdfflow::prelude::*;
use pdfflow::storage::{DatasetReader, WindowCache};
use pdfflow::util::timing::{fmt_bytes, fmt_secs, Stopwatch};

fn main() -> Result<()> {
    let mut cfg = ExperimentConfig::set1();
    // 1/100-volume analog (paper: 251x501x501, 1000 sims, 235 GB).
    cfg.dataset.dims = CubeDims::new(256, 64, 64);
    cfg.dataset.n_sims = 100;
    cfg.pipeline.window_lines = 16;
    cfg.slice = cfg.dataset.dims.nz * 201 / 501;
    cfg.data_dir = "data/example-seismic".into();

    println!("== pdfflow end-to-end: seismic slice ==");
    let sw = Stopwatch::start();
    let data = SyntheticDataset::generate(&cfg.dataset, &cfg.data_dir)?;
    println!(
        "dataset: {} files, {} ({}x{}x{} cube, {} observations/point) [{}]",
        data.files.len(),
        fmt_bytes(data.total_bytes()),
        data.spec.dims.nx,
        data.spec.dims.ny,
        data.spec.dims.nz,
        data.spec.n_sims,
        fmt_secs(sw.secs())
    );

    let backend = cfg.make_backend()?;
    let mut pipeline = Pipeline::new(
        &data,
        backend.as_ref(),
        SimCluster::new(cfg.cluster.clone()),
        cfg.pipeline.clone(),
    );

    // "Previously generated output data" -> decision tree (paper §5.3.1).
    let sw = Stopwatch::start();
    let model_error = pipeline.ensure_tree(cfg.train_slice, TypeSet::Ten, 25_000)?;
    println!(
        "decision tree: model error {:.4} [{}]",
        model_error,
        fmt_secs(sw.secs())
    );

    // All methods x type sets over the Slice-201 analog.
    println!(
        "\n{:<14} {:<8} {:>12} {:>12} {:>9} {:>7} {:>7}",
        "method", "types", "fit(real)", "fit(sim)", "E", "fits", "groups"
    );
    let mut baseline = [0.0f64; 2];
    let mut best: Option<(Method, TypeSet, f64)> = None;
    for (ti, types) in [TypeSet::Four, TypeSet::Ten].into_iter().enumerate() {
        for method in Method::ALL {
            let r = pipeline.run_slice(method, cfg.slice, types)?;
            println!(
                "{:<14} {:<8} {:>12} {:>12} {:>9.4} {:>7} {:>7}",
                method.name(),
                types.name(),
                fmt_secs(r.fit_real_s),
                fmt_secs(r.fit_sim_s),
                r.avg_error,
                r.fits,
                r.groups
            );
            if method == Method::Baseline {
                baseline[ti] = r.fit_sim_s;
            }
            // The paper's headline factor compares within 10-types.
            if ti == 1 && method != Method::Baseline
                && best.map_or(true, |(_, _, t)| r.fit_sim_s < t)
            {
                best = Some((method, types, r.fit_sim_s));
            }
        }
    }
    let (bm, bt, btime) = best.unwrap();
    println!(
        "\nHEADLINE: {} ({}) is {:.0}x faster than Baseline (10-types) on the simulated \
         LNCC cluster (paper reports up to 33x for Grouping+ML)",
        bm.name(),
        bt.name(),
        baseline[1] / btime.max(1e-12),
    );

    // Sampling survey (paper §5.4): slice features without fitting.
    let tree = pipeline.tree.clone().unwrap();
    let reader = DatasetReader::new(&data);
    let cache = WindowCache::new(512 << 20);
    let cluster = SimCluster::new(cfg.cluster.clone());
    let rep = run_sampling(
        &reader, &cache, backend.as_ref(), &cluster, &tree, cfg.slice, 0.1, Sampler::Random, 42,
    )?;
    println!(
        "\nsampling (rate 0.1): {} points, load {} compute {} — slice features:",
        rep.n_sampled,
        fmt_secs(rep.load_real_s),
        fmt_secs(rep.compute_real_s)
    );
    println!(
        "  avg mean {:.1}  avg std {:.1}",
        rep.features.avg_mean, rep.features.avg_std
    );
    for (i, pct) in rep.features.type_percentages.iter().enumerate() {
        if *pct > 0.005 {
            println!(
                "  {:<12} {:>5.1}%",
                DistType::from_id(i).unwrap().name(),
                pct * 100.0
            );
        }
    }
    Ok(())
}
